"""Population-based optimizers over the batched proxy engine.

Every algorithm evaluates whole populations per generation through
``DseEngine.evaluate_points`` — one padded, sharded, jitted proxy call per
generation, with the structure cache absorbing repeats across generations
(mutated traffic-only siblings and re-visited genomes rebuild nothing).
Area/power/cost come from the batched ``core.reports.report_arrays`` and are
memoized per structure key, feeding the constraint masks.

Optimizers share a small stateful interface — ``step()`` advances one
generation, ``state()``/``load_state()`` round-trip everything (RNG stream
included) through JSON — so ``opt.runner`` can checkpoint mid-run and resume
bit-identically.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, fields as dc_fields

import numpy as np

from ..core.reports import ReportArrays, report_arrays
from ..core.structure_cache import GLOBAL_STRUCTURE_CACHE
from ..dse.engine import DseEngine
from ..dse.genomes import PendingGenomeEval
from ..obs import metrics as _metrics
from ..obs.trace import span as _span
from .archive import ParetoArchive
from .operators import mutate_genes, tournament_select, uniform_crossover
from .space import SearchSpace


@dataclass(frozen=True)
class Budgets:
    """Constraint budgets; ``None`` leaves a dimension unconstrained."""
    max_interposer_area: float | None = None   # mm^2
    max_total_area: float | None = None        # mm^2 (chiplets + interposer)
    max_power: float | None = None             # W
    max_cost: float | None = None              # $

    def mask(self, reports: ReportArrays) -> np.ndarray:
        ok = np.ones(len(reports.power), bool)
        if self.max_interposer_area is not None:
            ok &= reports.interposer_area <= self.max_interposer_area
        if self.max_total_area is not None:
            ok &= reports.total_area <= self.max_total_area
        if self.max_power is not None:
            ok &= reports.power <= self.max_power
        if self.max_cost is not None:
            ok &= reports.cost <= self.max_cost
        return ok

    def to_dict(self) -> dict:
        return {k: v for k, v in self.__dict__.items() if v is not None}


@dataclass
class EvaluatedPopulation:
    genomes: np.ndarray       # [P, G]
    latency: np.ndarray       # [P] f64
    throughput: np.ndarray    # [P] f64
    feasible: np.ndarray      # [P] bool
    reports: ReportArrays
    # Robustness columns [P] from the fault grid (ISSUE 9): None on
    # pristine runs. Keys: expected/worst latency+throughput,
    # disconnect_prob, min_reachable_fraction, pristine_latency/throughput.
    extra: dict | None = None


def _pop_apply(fn, *pops: EvaluatedPopulation) -> EvaluatedPopulation:
    """Apply ``fn`` field-wise over populations (and their report columns):
    the dataclasses are the single source of truth for what a population
    carries, so concatenation/selection never drop a field."""
    kw = {}
    for f in dc_fields(EvaluatedPopulation):
        vals = [getattr(p, f.name) for p in pops]
        if f.name == "reports":
            kw[f.name] = ReportArrays(**{
                g.name: fn(*[getattr(v, g.name) for v in vals])
                for g in dc_fields(ReportArrays)})
        elif f.name == "extra":
            if any(v is None for v in vals):
                kw[f.name] = None
            else:
                kw[f.name] = {k: fn(*[v[k] for v in vals])
                              for k in vals[0]}
        else:
            kw[f.name] = fn(*vals)
    return EvaluatedPopulation(**kw)


_POP_DTYPES = {"genomes": np.int64, "latency": np.float64,
               "throughput": np.float64, "feasible": bool}


def _pop_to_state(ev: EvaluatedPopulation | None):
    if ev is None:
        return None
    state = {k: np.asarray(getattr(ev, k)).tolist() for k in _POP_DTYPES}
    state["reports"] = {f.name: np.asarray(getattr(ev.reports, f.name)).tolist()
                        for f in dc_fields(ReportArrays)}
    if ev.extra is not None:
        state["extra"] = {k: np.asarray(v).tolist()
                          for k, v in ev.extra.items()}
    return state


def _pop_from_state(state) -> EvaluatedPopulation | None:
    if state is None:
        return None
    # .get: checkpoints written before a report column existed restore
    # with the column's constructor default instead of crashing.
    reports = {f.name: np.asarray(state["reports"][f.name], np.float64)
               for f in dc_fields(ReportArrays)
               if state["reports"].get(f.name) is not None}
    extra = state.get("extra")
    if extra is not None:
        extra = {k: np.asarray(v, np.float64) for k, v in extra.items()}
    return EvaluatedPopulation(
        **{k: np.asarray(state[k], dt) for k, dt in _POP_DTYPES.items()},
        reports=ReportArrays(**reports), extra=extra)


class PopulationEvaluator:
    """genomes -> proxies + constraint masks, counting evaluations.

    By default populations go through the engine's fused **device path**
    (``DseEngine.evaluate_genomes``): decode, geometry, routing tables, and
    proxies run as one jitted program per (bucketed population, node count)
    shape, and no ``DesignPoint`` is ever materialized — the optimizer inner
    loop never touches per-design Python. The classic host path
    (``evaluate_points`` through the structure cache) remains for spaces the
    device cannot reproduce (updown_random-routed adjacency spaces), for
    ``validate=True`` runs, and for explicit ``device_path=False`` callers;
    its reports are memoized by ``DesignPoint.structure_key()``."""

    def __init__(self, space: SearchSpace, engine: DseEngine | None = None,
                 budgets: Budgets | None = None, validate: bool = False,
                 device_path: bool | None = None, faults=None):
        self.space = space
        self.engine = engine if engine is not None else DseEngine()
        self.budgets = budgets or Budgets()
        self.validate = validate
        self.device_path = device_path
        self.faults = faults          # faults.objectives.FaultSetup | None
        self.n_evals = 0
        self._report_cache: dict = {}
        if faults is not None and not self.engine.supports_faults(space):
            raise ValueError(
                f"fault-aware evaluation needs the fused device fault "
                f"grid, which {type(space).__name__} (routing "
                f"{getattr(space, 'routing', None)!r}) does not support")

    def _use_device_path(self) -> bool:
        if self.device_path is not None:
            return self.device_path
        return not self.validate and self.engine.supports_genomes(self.space)

    def _reports_for(self, points) -> ReportArrays:
        missing, missing_keys = [], set()
        for pt in points:
            key = pt.structure_key()
            if key not in self._report_cache and key not in missing_keys:
                missing.append(pt)
                missing_keys.add(key)
        if missing:
            # evaluate_points(keep_designs=True) retained the built Design
            # in the structure cache; fall back to rebuilding only when an
            # entry was evicted between the proxy call and this one.
            def design_of(pt):
                entry = GLOBAL_STRUCTURE_CACHE.get(pt.structure_key())
                design = entry.extra.get("design") if entry else None
                return design if design is not None else pt.build()

            built = report_arrays([design_of(pt) for pt in missing])
            for i, pt in enumerate(missing):
                self._report_cache[pt.structure_key()] = (
                    built.total_chiplet_area[i], built.interposer_area[i],
                    built.power[i], built.cost[i],
                    built.reachable_fraction[i])
        cols = np.asarray([self._report_cache[pt.structure_key()]
                           for pt in points], np.float64)
        return ReportArrays(total_chiplet_area=cols[:, 0],
                            interposer_area=cols[:, 1],
                            power=cols[:, 2], cost=cols[:, 3],
                            reachable_fraction=cols[:, 4])

    def dispatch(self, genomes: np.ndarray) -> "PendingPopulationEval":
        """Start evaluating a population without blocking on the device.

        On the device path the fused sharded program is dispatched and the
        host returns immediately; ``PendingPopulationEval.result()``
        materializes metrics, reports, and the constraint mask. The host
        path has no asynchrony to exploit — it evaluates eagerly and wraps
        the finished result, so callers can pipeline uniformly.
        Evaluations are counted at dispatch time."""
        genomes = np.asarray(genomes, np.int64)
        if self.faults is not None:
            sc = self.faults.scenarios
            with _span("opt.dispatch", path="faults", evals=len(genomes),
                       scenarios=sc.n_scenarios):
                pending = self.engine.evaluate_genomes_faults_async(
                    self.space, genomes, sc.link_fail, sc.node_fail)
            self.n_evals += len(genomes)
            return PendingPopulationEval(
                lambda: self._finalize_faults(genomes, pending.result()))
        if self._use_device_path():
            with _span("opt.dispatch", path="device", evals=len(genomes)):
                pending = self.engine.evaluate_genomes_async(self.space,
                                                             genomes)
            self.n_evals += len(genomes)
            return PendingPopulationEval(
                lambda: self._finalize(genomes, pending.result(), None))
        with _span("opt.dispatch", path="host", evals=len(genomes)):
            points = self.space.decode(genomes, start_index=self.n_evals)
            self.n_evals += len(points)
            res = self.engine.evaluate_points(
                points, validate=self.validate, n_pad=self.space.max_nodes,
                round_hops=True, keep_designs=True)
        return PendingPopulationEval(
            lambda: self._finalize(genomes, res, points))

    def _finalize(self, genomes, res, points) -> EvaluatedPopulation:
        from ..faults.harness import quarantine_nonfinite
        with _span("opt.finalize", evals=len(genomes),
                   path="device" if points is None else "host"):
            reports = (res.reports if points is None
                       else self._reports_for(points))
            lat = np.asarray(res.latency, np.float64)
            thr = np.asarray(res.throughput, np.float64)
            feasible = self.budgets.mask(reports)
            # NaN/inf rows get finite penalty scores + feasible=False and
            # land in the quarantine list — selection math stays finite,
            # the archive never ingests them (ISSUE 9).
            lat, thr, feasible = quarantine_nonfinite(
                genomes, lat, thr, feasible, context="eval")
            return EvaluatedPopulation(genomes=genomes, latency=lat,
                                       throughput=thr, feasible=feasible,
                                       reports=reports)

    def _finalize_faults(self, genomes, grid) -> EvaluatedPopulation:
        """Reduce the [P, F] fault grid into robust Pareto objectives: the
        configured mode's latency/throughput become THE archive axes, the
        disconnection-probability constraint folds into feasibility, and
        the remaining robustness columns ride along in ``extra``."""
        from ..faults.harness import quarantine_nonfinite
        from ..faults.objectives import reduce_grid, robust_columns
        with _span("opt.finalize", evals=len(genomes), path="faults"):
            sc = self.faults.scenarios
            reduced = reduce_grid(grid.latency, grid.throughput,
                                  grid.reachable_fraction, sc.weights)
            lat, thr, ok = robust_columns(reduced, self.faults.objectives)
            try:
                pristine = sc.names.index("pristine")
            except ValueError:
                pristine = 0
            extra = dict(reduced)
            extra["pristine_latency"] = np.asarray(
                grid.latency[:, pristine], np.float64)
            extra["pristine_throughput"] = np.asarray(
                grid.throughput[:, pristine], np.float64)
            feasible = self.budgets.mask(grid.reports) & ok
            lat, thr, feasible = quarantine_nonfinite(
                genomes, lat, thr, feasible, context="faults")
            return EvaluatedPopulation(genomes=genomes, latency=lat,
                                       throughput=thr, feasible=feasible,
                                       reports=grid.reports, extra=extra)

    def __call__(self, genomes: np.ndarray) -> EvaluatedPopulation:
        return self.dispatch(genomes).result()


class PendingPopulationEval(PendingGenomeEval):
    """In-flight population evaluation (the same memoized-finisher contract
    as ``PendingGenomeEval``); ``result()`` blocks on the device, builds
    the constraint mask, and is idempotent."""


# ---------------------------------------------------------------------------
# NSGA-II machinery
# ---------------------------------------------------------------------------

def nondominated_ranks(latency: np.ndarray, throughput: np.ndarray,
                       feasible: np.ndarray) -> np.ndarray:
    """Constraint-dominated non-dominated sorting: rank 0 is the first front;
    every infeasible point ranks after every feasible one.

    Vectorized front peeling — one Python iteration per *front* (the
    staircase scan is a cumulative max over the sort order, the duplicate
    fold one broadcast comparison), so the merged-population sort stays off
    the optimizer's critical path. Output is identical to the original
    per-point scan (same staircase with tol=0, duplicates of a front member
    join its rank, an all--inf-throughput remainder closes out together).
    """
    P = len(latency)
    ranks = np.full(P, P, np.int64)
    lat = np.where(np.isfinite(latency), latency, np.inf)
    thr = np.where(np.isfinite(throughput), throughput, -np.inf)
    remaining = np.asarray(feasible, bool).copy()
    rank = 0
    while remaining.any():
        idx = np.nonzero(remaining)[0]
        order = idx[np.lexsort((-thr[idx], lat[idx]))]
        t = thr[order]
        # staircase with tol=0: keep strictly rising throughput. A skipped
        # point never exceeds the running best, so the cumulative max over
        # ALL previous equals the best over kept ones — the scan is exact.
        prev_best = np.maximum.accumulate(
            np.concatenate(([-np.inf], t[:-1])))
        keep = t > prev_best
        if not keep.any():
            # every remaining point has -inf throughput: no staircase, and
            # they are mutually incomparable here — close them out together
            ranks[idx] = rank
            remaining[idx] = False
            rank += 1
            continue
        # duplicates of a front member are non-dominated too: keep any point
        # equal in both objectives to a front member in the same rank
        f_lat, f_thr = lat[order[keep]], thr[order[keep]]
        eq = np.any((lat[idx][:, None] == f_lat[None, :]) &
                    (thr[idx][:, None] == f_thr[None, :]), axis=1)
        members = idx[eq]
        ranks[members] = rank
        remaining[members] = False
        rank += 1
    infeasible = np.nonzero(~np.asarray(feasible, bool))[0]
    ranks[infeasible] = rank
    return ranks


def crowding_distance(latency: np.ndarray, throughput: np.ndarray,
                      ranks: np.ndarray) -> np.ndarray:
    """Per-point crowding distance within its rank (inf at boundaries)."""
    P = len(latency)
    dist = np.zeros(P, np.float64)
    for r in np.unique(ranks):
        idx = np.nonzero(ranks == r)[0]
        if len(idx) <= 2:
            dist[idx] = np.inf
            continue
        for obj in (latency, throughput):
            vals = np.where(np.isfinite(obj[idx]), obj[idx], 0.0)
            order = idx[np.argsort(vals, kind="stable")]
            span = vals.max() - vals.min()
            dist[order[0]] = dist[order[-1]] = np.inf
            if span <= 0:
                continue
            v = np.sort(vals, kind="stable")
            dist[order[1:-1]] += (v[2:] - v[:-2]) / span
    return dist


def _selection_scores(ranks: np.ndarray, crowd: np.ndarray) -> np.ndarray:
    """Scalar key for tournaments: lower rank wins, crowding breaks ties."""
    return ranks.astype(np.float64) * 1e6 - np.minimum(crowd, 1e5)


def _rng_state(rng: np.random.Generator) -> dict:
    state = rng.bit_generator.state
    # JSON round-trips Python ints of any size; copy to plain dicts.
    return {"bit_generator": state["bit_generator"],
            "state": {k: int(v) for k, v in state["state"].items()},
            "has_uint32": int(state.get("has_uint32", 0)),
            "uinteger": int(state.get("uinteger", 0))}


def _restore_rng(state: dict) -> np.random.Generator:
    rng = np.random.default_rng()
    rng.bit_generator.state = {
        "bit_generator": state["bit_generator"],
        "state": dict(state["state"]),
        "has_uint32": state["has_uint32"],
        "uinteger": state["uinteger"]}
    return rng


class OptimizerBase:
    """Shared stepping/checkpointing shell for the three searches."""

    algo = "base"

    def __init__(self, space: SearchSpace, evaluator: PopulationEvaluator,
                 seed: int = 0, archive: ParetoArchive | None = None):
        self.space = space
        self.evaluator = evaluator
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self.archive = archive if archive is not None else ParetoArchive()
        self.generation = 0

    # -- checkpointing ------------------------------------------------------
    def state(self, meta: dict | None = None) -> dict:
        """Serializable optimizer state. ``meta`` (from ``snapshot_meta``)
        substitutes the RNG/eval-count/generation triple captured at an
        earlier moment — the async driver snapshots it right after a
        generation completes, then builds the checkpoint while the next
        generation's device call is in flight (the archive and population
        are only mutated by the deferred ingest that runs first, so the
        resulting checkpoint is bit-identical to the synchronous one)."""
        if meta is None:
            meta = self.snapshot_meta()
        return {"algo": self.algo, "seed": self.seed,
                "generation": meta["generation"],
                "rng": meta["rng"],
                "n_evals": meta["n_evals"],
                "archive": self.archive.to_dicts(),
                **self._extra_state()}

    def snapshot_meta(self) -> dict:
        """The cheap, mutation-prone part of the state (RNG stream, eval
        count, generation) — captured before the next generation's RNG
        draws happen."""
        return {"generation": self.generation,
                "rng": _rng_state(self.rng),
                "n_evals": self.evaluator.n_evals}

    def load_state(self, state: dict) -> None:
        if state.get("algo") != self.algo:
            raise ValueError(f"checkpoint is for algo {state.get('algo')!r}, "
                             f"this optimizer is {self.algo!r}")
        self.seed = state["seed"]
        self.generation = state["generation"]
        self.rng = _restore_rng(state["rng"])
        self.evaluator.n_evals = state["n_evals"]
        self.archive = ParetoArchive.from_dicts(state["archive"])
        self._load_extra_state(state)

    def _extra_state(self) -> dict:
        return {}

    def _load_extra_state(self, state: dict) -> None:
        pass

    # -- stepping -----------------------------------------------------------
    def _ingest(self, ev: EvaluatedPopulation) -> None:
        t0 = time.perf_counter()
        with _span("opt.ingest", evals=len(ev.latency)):
            metrics = {"interposer_area": ev.reports.interposer_area,
                       "total_chiplet_area": ev.reports.total_chiplet_area,
                       "power": ev.reports.power, "cost": ev.reports.cost,
                       "reachable_fraction": ev.reports.reachable_fraction}
            if ev.extra is not None:
                metrics.update(ev.extra)
            self.archive.update(
                ev.latency, ev.throughput, feasible=ev.feasible,
                payloads=[g.tolist() for g in ev.genomes],
                metrics=metrics)
        _metrics.histogram("opt.ingest_s").observe(time.perf_counter() - t0)

    def begin_step(self) -> np.ndarray:
        """Produce the next population to evaluate. Every RNG draw that
        precedes the evaluation happens here, in the same order as
        ``step`` — the sync and async drivers therefore consume one
        identical RNG stream."""
        raise NotImplementedError

    def finish_step(self, ev: EvaluatedPopulation,
                    ingest: bool = True) -> None:
        """Fold an evaluated population back in (selection/acceptance —
        including any post-evaluation RNG draws) and advance the
        generation counter. With ``ingest=False`` the archive update is the
        caller's responsibility (the async driver defers it into the window
        where the next generation's device call is in flight; the archive
        feeds no selection decision, so ordering it later is exact)."""
        raise NotImplementedError

    def step(self) -> None:
        self.finish_step(self.evaluator(self.begin_step()))


class EvolutionarySearch(OptimizerBase):
    """NSGA-II-style evolutionary multi-objective search: non-dominated
    sorting + crowding, binary tournaments, uniform crossover, per-gene
    mutation, (mu + lambda) environmental selection."""

    algo = "nsga2"

    def __init__(self, space, evaluator, seed: int = 0, pop_size: int = 24,
                 mutation_rate: float | None = None,
                 crossover_prob: float = 0.9, archive=None):
        super().__init__(space, evaluator, seed, archive)
        self.pop_size = pop_size
        self.mutation_rate = (mutation_rate if mutation_rate is not None
                              else max(1.0 / space.genome_length, 0.01))
        self.crossover_prob = crossover_prob
        self.pop: EvaluatedPopulation | None = None

    def _extra_state(self) -> dict:
        return {"pop_size": self.pop_size,
                "mutation_rate": self.mutation_rate,
                "crossover_prob": self.crossover_prob,
                "pop": _pop_to_state(self.pop)}

    def _load_extra_state(self, state: dict) -> None:
        self.pop_size = state["pop_size"]
        self.mutation_rate = state["mutation_rate"]
        self.crossover_prob = state["crossover_prob"]
        self.pop = _pop_from_state(state.get("pop"))

    def begin_step(self) -> np.ndarray:
        if self.pop is None:
            return self.space.sample(self.rng, self.pop_size)
        pop = self.pop
        ranks = nondominated_ranks(pop.latency, pop.throughput, pop.feasible)
        crowd = crowding_distance(pop.latency, pop.throughput, ranks)
        scores = _selection_scores(ranks, crowd)
        pa = pop.genomes[tournament_select(scores, self.pop_size, self.rng)]
        pb = pop.genomes[tournament_select(scores, self.pop_size, self.rng)]
        cross = self.rng.random(self.pop_size) < self.crossover_prob
        children = np.where(cross[:, None],
                            uniform_crossover(pa, pb, self.rng), pa)
        return self.space.repair(
            mutate_genes(children, self.space.cardinalities,
                         self.mutation_rate, self.rng))

    def finish_step(self, ev: EvaluatedPopulation,
                    ingest: bool = True) -> None:
        if self.pop is None:
            self.pop = ev
            if ingest:
                self._ingest(ev)
            self.generation += 1
            return
        if ingest:
            self._ingest(ev)
        # (mu + lambda) environmental selection over parents + children
        merged = _pop_apply(lambda a, b: np.concatenate([a, b]),
                            self.pop, ev)
        m_ranks = nondominated_ranks(merged.latency, merged.throughput,
                                     merged.feasible)
        m_crowd = crowding_distance(merged.latency, merged.throughput, m_ranks)
        order = np.sort(np.lexsort((-m_crowd, m_ranks))[:self.pop_size])
        self.pop = _pop_apply(lambda x: x[order], merged)
        self.generation += 1


class SimulatedAnnealing(OptimizerBase):
    """Parallel-chain simulated annealing on the scalarized objective
    ``latency / throughput`` (monotone in both proxies); every chain's
    proposal is evaluated in the same batched proxy call."""

    algo = "sa"

    def __init__(self, space, evaluator, seed: int = 0, n_chains: int = 24,
                 mutation_rate: float | None = None, t0: float = 1.0,
                 cooling: float = 0.95, archive=None):
        super().__init__(space, evaluator, seed, archive)
        self.n_chains = n_chains
        self.mutation_rate = (mutation_rate if mutation_rate is not None
                              else max(2.0 / space.genome_length, 0.01))
        self.t0 = t0
        self.cooling = cooling
        self.chains: np.ndarray | None = None
        self.energies: np.ndarray | None = None

    @staticmethod
    def _energy(ev: EvaluatedPopulation) -> np.ndarray:
        ok = ev.feasible & (ev.throughput > 0)
        return np.where(ok, ev.latency / np.maximum(ev.throughput, 1e-30),
                        1e30)

    @property
    def temperature(self) -> float:
        return self.t0 * self.cooling ** max(self.generation - 1, 0)

    def _extra_state(self) -> dict:
        return {"n_chains": self.n_chains,
                "mutation_rate": self.mutation_rate,
                "t0": self.t0, "cooling": self.cooling,
                "chains": None if self.chains is None
                else self.chains.tolist(),
                "energies": None if self.energies is None
                else self.energies.tolist()}

    def _load_extra_state(self, state: dict) -> None:
        self.n_chains = state["n_chains"]
        self.mutation_rate = state["mutation_rate"]
        self.t0 = state["t0"]
        self.cooling = state["cooling"]
        self.chains = (None if state["chains"] is None
                       else np.asarray(state["chains"], np.int64))
        self.energies = (None if state["energies"] is None
                         else np.asarray(state["energies"], np.float64))

    def begin_step(self) -> np.ndarray:
        if self.chains is None:
            self.chains = self.space.sample(self.rng, self.n_chains)
            return self.chains
        self._proposals = self.space.repair(
            mutate_genes(self.chains, self.space.cardinalities,
                         self.mutation_rate, self.rng))
        return self._proposals

    def finish_step(self, ev: EvaluatedPopulation,
                    ingest: bool = True) -> None:
        if ingest:
            self._ingest(ev)
        if self.energies is None:
            self.energies = self._energy(ev)
            self.generation += 1
            return
        # the accept gate draws AFTER the evaluation — still one shared RNG
        # stream, because finish_step always runs before the next begin_step
        energy = self._energy(ev)
        d = energy - self.energies
        temp = max(self.temperature, 1e-12)
        accept = (d < 0) | (self.rng.random(self.n_chains)
                            < np.exp(-np.clip(d, 0, 700) / temp))
        self.chains = np.where(accept[:, None], self._proposals, self.chains)
        self.energies = np.where(accept, energy, self.energies)
        self.generation += 1


class RandomSearch(OptimizerBase):
    """Equal-budget baseline: independent uniform samples every generation."""

    algo = "random"

    def __init__(self, space, evaluator, seed: int = 0, batch_size: int = 24,
                 archive=None):
        super().__init__(space, evaluator, seed, archive)
        self.batch_size = batch_size

    def _extra_state(self) -> dict:
        return {"batch_size": self.batch_size}

    def _load_extra_state(self, state: dict) -> None:
        self.batch_size = state["batch_size"]

    def begin_step(self) -> np.ndarray:
        return self.space.sample(self.rng, self.batch_size)

    def finish_step(self, ev: EvaluatedPopulation,
                    ingest: bool = True) -> None:
        if ingest:
            self._ingest(ev)
        self.generation += 1


ALGORITHMS = {
    "nsga2": EvolutionarySearch,
    "sa": SimulatedAnnealing,
    "random": RandomSearch,
}
