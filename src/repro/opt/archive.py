"""Multi-objective Pareto archive for ICI design optimization.

Generalizes the sweep-side ``dse/pareto.py`` front computation (which now
re-exports from here) into a maintained archive the optimizers update every
generation:

* objectives are (minimize latency, maximize throughput) — the paper's two
  proxies;
* constraint masks (area/power/cost budgets from batched ``core/reports.py``)
  filter candidates before they enter;
* the 2-D hypervolume indicator w.r.t. a reference point measures front
  quality, so searches with different budgets are comparable.

Everything is plain numpy: archives hold tens of points, the heavy math is in
the proxy engine.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


def staircase_front(latency: np.ndarray, throughput: np.ndarray,
                    idx: np.ndarray, tol: float = 0.0) -> np.ndarray:
    """The one dominance scan every front computation in this package uses:
    among candidate indices ``idx``, sort by (latency asc, throughput desc —
    stable, so earlier candidates win exact ties) and keep the staircase of
    strictly (by ``tol``) rising throughput. Returned in scan order."""
    lat = np.asarray(latency, np.float64)
    thr = np.asarray(throughput, np.float64)
    order = idx[np.lexsort((-thr[idx], lat[idx]))]
    front = []
    best_thr = -np.inf
    for i in order:
        if thr[i] > best_thr + tol:
            front.append(int(i))
            best_thr = thr[i]
    return np.asarray(front, np.int64)


def pareto_front(latency: np.ndarray, throughput: np.ndarray,
                 mask: np.ndarray | None = None) -> np.ndarray:
    """Indices of the Pareto-optimal points (minimize latency, maximize
    throughput), sorted by latency. ``mask`` filters candidates (e.g. an
    area budget)."""
    idx = np.arange(len(np.asarray(latency, np.float64)))
    if mask is not None:
        idx = idx[np.asarray(mask, bool)]
    return staircase_front(latency, throughput, idx, tol=1e-12)


def hypervolume_2d(latency, throughput,
                   ref_latency: float, ref_throughput: float = 0.0) -> float:
    """2-D hypervolume of the (min-latency, max-throughput) front w.r.t. the
    reference point ``(ref_latency, ref_throughput)``: the area of the
    objective-space region dominated by the front and dominating the
    reference. Points that do not strictly dominate the reference contribute
    nothing; empty input gives 0."""
    lat = np.asarray(latency, np.float64).ravel()
    thr = np.asarray(throughput, np.float64).ravel()
    keep = (np.isfinite(lat) & np.isfinite(thr) &
            (lat < ref_latency) & (thr > ref_throughput))
    if not keep.any():
        return 0.0
    lat, thr = lat[keep], thr[keep]
    front = pareto_front(lat, thr)
    # Front sorted by latency ascending has strictly increasing throughput:
    # each point adds the rectangle up from the previous throughput level.
    hv = 0.0
    prev_thr = ref_throughput
    for i in front:
        hv += (ref_latency - lat[i]) * (thr[i] - prev_thr)
        prev_thr = thr[i]
    return float(hv)


@dataclass
class ArchiveEntry:
    """One non-dominated design kept by the archive."""
    latency: float
    throughput: float
    metrics: dict = field(default_factory=dict)   # area/power/cost, ...
    payload: object = None                        # genome / DesignPoint info

    def to_dict(self) -> dict:
        payload = self.payload
        if isinstance(payload, np.ndarray):
            payload = payload.tolist()
        return {"latency": self.latency, "throughput": self.throughput,
                "metrics": dict(self.metrics), "payload": payload}

    @classmethod
    def from_dict(cls, d: dict) -> "ArchiveEntry":
        return cls(latency=float(d["latency"]),
                   throughput=float(d["throughput"]),
                   metrics=dict(d.get("metrics") or {}),
                   payload=d.get("payload"))


class ParetoArchive:
    """Maintained set of mutually non-dominated (latency, throughput) points.

    ``update`` folds a batch of candidates in: infeasible and non-finite
    candidates are dropped, then the union of archive and candidates is
    reduced to its non-dominated subset (exact duplicates keep the earliest
    entry, so the archive is stable under re-insertion)."""

    def __init__(self):
        self.entries: list[ArchiveEntry] = []

    def __len__(self) -> int:
        return len(self.entries)

    @property
    def latencies(self) -> np.ndarray:
        return np.asarray([e.latency for e in self.entries], np.float64)

    @property
    def throughputs(self) -> np.ndarray:
        return np.asarray([e.throughput for e in self.entries], np.float64)

    def update(self, latency, throughput, feasible=None, payloads=None,
               metrics: dict | None = None) -> int:
        """Insert a candidate batch; returns how many new entries survived.

        ``feasible``: bool mask [B] (constraint budgets); ``payloads``: one
        opaque object per candidate; ``metrics``: dict of [B] arrays attached
        per-entry (e.g. the batched report columns)."""
        lat = np.asarray(latency, np.float64).ravel()
        thr = np.asarray(throughput, np.float64).ravel()
        ok = np.isfinite(lat) & np.isfinite(thr)
        if feasible is not None:
            ok &= np.asarray(feasible, bool).ravel()
        candidates = []
        for i in np.nonzero(ok)[0]:
            entry_metrics = ({k: float(np.asarray(v).ravel()[i])
                              for k, v in metrics.items()} if metrics else {})
            payload = payloads[i] if payloads is not None else None
            candidates.append(ArchiveEntry(
                latency=float(lat[i]), throughput=float(thr[i]),
                metrics=entry_metrics, payload=payload))
        if not candidates:
            return 0
        merged = self.entries + candidates
        m_lat = np.asarray([e.latency for e in merged])
        m_thr = np.asarray([e.throughput for e in merged])
        # existing entries come first, so they win exact ties in the scan
        keep = sorted(staircase_front(m_lat, m_thr,
                                      np.arange(len(merged)), tol=0.0))
        survivors = [merged[i] for i in keep]
        added = sum(1 for i in keep if i >= len(self.entries))
        self.entries = survivors
        return added

    def front(self) -> list[ArchiveEntry]:
        """Entries sorted by latency (throughput is then ascending too)."""
        return sorted(self.entries, key=lambda e: (e.latency, e.throughput))

    def hypervolume(self, ref_latency: float,
                    ref_throughput: float = 0.0) -> float:
        return hypervolume_2d(self.latencies, self.throughputs,
                              ref_latency, ref_throughput)

    def to_dicts(self) -> list[dict]:
        return [e.to_dict() for e in self.front()]

    @classmethod
    def from_dicts(cls, rows: list[dict]) -> "ParetoArchive":
        archive = cls()
        archive.entries = [ArchiveEntry.from_dict(r) for r in rows]
        return archive
