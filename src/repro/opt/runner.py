"""Checkpointable optimizer runner + CLI.

Mirrors the DSE engine's cursor-file story at the optimizer level: after
every generation the full optimizer state — RNG stream, population, archive,
evaluation count — is written atomically to a JSON checkpoint. A run that is
killed mid-search resumes from the checkpoint and reproduces exactly the
archive an uninterrupted run would have produced (asserted in
``tests/test_opt.py``).

CLI::

    PYTHONPATH=src python -m repro.opt --space adjacency --n-chiplets 32 \
        --algo nsga2 --generations 20 --pop-size 24 \
        --max-interposer-area 2500 --checkpoint opt_ckpt.json --out front.json
"""
from __future__ import annotations

import argparse
import json
import os
import time
from dataclasses import dataclass, field

import numpy as np

from ..faults.harness import (CheckpointCorruptError, graceful_shutdown,
                              json_digest)
from ..obs import metrics as _metrics
from ..obs.log import get_logger
from ..obs.trace import enable_tracing, span as _span
from ..utils.version import check_version_stamp, version_stamp
from .algorithms import ALGORITHMS, Budgets, OptimizerBase, PopulationEvaluator
from .archive import ParetoArchive
from .space import AdjacencySpace, ParametricSpace, SearchSpace

_LOG = get_logger("opt")


@dataclass
class OptResult:
    archive: ParetoArchive
    n_evals: int
    generations: int
    # Per-generation hypervolume for the generations executed by *this*
    # run() call: history[i] belongs to generation history_start + 1 + i.
    # After a checkpoint resume, history_start > 0 and pre-resume
    # generations have no entries.
    history: list = field(default_factory=list)
    history_start: int = 0

    def to_rows(self, space: SearchSpace | None = None) -> list[dict]:
        rows = []
        for e in self.archive.front():
            row = {"latency": e.latency, "throughput": e.throughput,
                   **e.metrics}
            if space is not None and e.payload is not None:
                row.update(space.describe(np.asarray(e.payload, np.int64)))
            rows.append(row)
        return rows


def save_checkpoint(path: str, optimizer: OptimizerBase,
                    meta: dict | None = None) -> None:
    """Atomic write so a kill mid-dump never corrupts the resume point.
    ``meta`` substitutes a snapshot of the RNG/eval-count/generation triple
    captured earlier (the async driver's deferred checkpointing). The
    snapshot carries a version stamp so a resume from a different
    repro/jax version warns instead of silently mixing trajectories.

    Format 2 (ISSUE 9): the state is wrapped in an envelope with a
    canonical sha256, the bytes are fsynced before the atomic rename, and
    the previous snapshot is rotated to ``<path>.prev`` first — so a
    SIGKILL at any instant leaves either the new verified snapshot, the
    old verified snapshot, or both, never a torn resume point."""
    with _span("opt.checkpoint", path=path):
        state = optimizer.state(meta)
        state["versions"] = version_stamp()
        payload = {"format": 2, "sha256": json_digest(state), "state": state}
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(path):
            os.replace(path, path + ".prev")
        os.replace(tmp, path)


class AsyncStepper:
    """Double-buffered generation pipeline over ``OptimizerBase``'s
    begin/finish split (the async driver of ISSUE 5).

    Each ``step()`` completes exactly one generation, but in pipelined
    order: first the *previous* generation's deferred work (archive ingest,
    hypervolume, checkpoint write) runs while the current generation's
    device call — dispatched at the end of the previous ``step()`` with
    ``PopulationEvaluator.dispatch`` — is still in flight; only then does
    the driver block on the device, fold the results in, and dispatch the
    next generation. The RNG stream, archive contents, per-generation
    checkpoints, and eval counts are bit-identical to synchronous stepping
    (asserted in tests/test_opt.py): every RNG draw happens in the same
    order, the deferred ingest feeds no selection decision, and checkpoints
    are built from a state snapshot taken before the next generation's
    draws.

    ``on_generation(optimizer, meta, ev)`` runs inside the overlap window,
    after the deferred ingest — the place for checkpoint writes and
    progress reporting.
    """

    def __init__(self, optimizer: OptimizerBase, generations: int,
                 on_generation=None):
        self.optimizer = optimizer
        self.generations = generations
        self.on_generation = on_generation
        self._pending = None
        self._deferred = None

    def _flush_deferred(self) -> None:
        if self._deferred is None:
            return
        ev, meta = self._deferred
        self._deferred = None
        # This is the host work hidden behind the in-flight device call;
        # its duration vs the subsequent device wait is the async overlap
        # efficiency reported by repro.obs.
        t0 = time.perf_counter()
        with _span("opt.flush_deferred", generation=meta["generation"]):
            self.optimizer._ingest(ev)
            if self.on_generation is not None:
                self.on_generation(self.optimizer, meta, ev)
        _metrics.counter("opt.async.host_s").inc(time.perf_counter() - t0)

    def step(self) -> bool:
        """Complete one generation; returns False once the target count is
        reached (after flushing the last generation's deferred work)."""
        opt = self.optimizer
        t_start = time.perf_counter()
        # Deferred work of generation g-1 executes while generation g's
        # dispatched evaluation runs on the device.
        self._flush_deferred()
        if opt.generation >= self.generations:
            return False
        if self._pending is None:
            self._pending = opt.evaluator.dispatch(opt.begin_step())
        t0 = time.perf_counter()
        with _span("opt.device_wait", generation=opt.generation):
            ev = self._pending.result()
        _metrics.counter("opt.async.wait_s").inc(time.perf_counter() - t0)
        self._pending = None
        with _span("opt.generation", generation=opt.generation,
                   mode="async"):
            opt.finish_step(ev, ingest=False)
            meta = opt.snapshot_meta()
            if opt.generation < self.generations:
                # dispatch generation g+1 before generation g's bookkeeping:
                # the device computes through the entire deferred window
                self._pending = opt.evaluator.dispatch(opt.begin_step())
        self._deferred = (ev, meta)
        dt = time.perf_counter() - t_start
        _metrics.histogram("opt.generation_s").observe(dt)
        if dt > 0:
            _metrics.histogram("opt.evals_per_s").observe(
                len(ev.latency) / dt)
        return True

    def run(self, stop=None) -> None:
        while self.step():
            if stop is not None and stop.requested():
                break
        self.drain()

    def drain(self) -> None:
        """Finish the in-flight generation (its device work is already
        paid for) and flush deferred bookkeeping, so an early exit leaves
        the same per-generation checkpoint a full run would have written
        at this point."""
        self._flush_deferred()
        if self._pending is None:
            return
        opt = self.optimizer
        ev = self._pending.result()
        self._pending = None
        opt.finish_step(ev, ingest=False)
        meta = opt.snapshot_meta()
        opt._ingest(ev)
        if self.on_generation is not None:
            self.on_generation(opt, meta, ev)


def load_checkpoint(path: str) -> dict:
    """Load ONE checkpoint file, verifying the format-2 sha256 envelope.
    Pre-format-2 flat states (no envelope) load without verification.
    Raises ``CheckpointCorruptError`` on digest mismatch and the usual
    OSError/JSONDecodeError on unreadable bytes."""
    with open(path) as f:
        payload = json.load(f)
    if isinstance(payload, dict) and payload.get("format") == 2:
        state = payload["state"]
        want = payload.get("sha256")
        if want is not None and json_digest(state) != want:
            raise CheckpointCorruptError(f"{path}: sha256 mismatch "
                                         f"(torn or tampered snapshot)")
        return state
    return payload


def load_checkpoint_resilient(path: str) -> tuple[dict | None, str | None]:
    """Warn-then-fall-back resume ladder: try ``path``, then the rotated
    ``path.prev``; a candidate that is corrupt or unreadable logs a warning
    and bumps ``ckpt.corrupt`` instead of crashing the run. Returns
    ``(state, source_path)`` or ``(None, None)`` when nothing loads."""
    for cand in (path, path + ".prev"):
        if not os.path.exists(cand):
            continue
        try:
            return load_checkpoint(cand), cand
        except Exception as e:
            _metrics.counter("ckpt.corrupt", stage="opt").inc()
            _LOG.warning(f"[opt] checkpoint {cand} rejected "
                         f"({type(e).__name__}: {e}); trying fallback")
    return None, None


class OptRunner:
    """Drives an optimizer for N generations with per-generation
    checkpointing and optional hypervolume tracking.

    ``async_pipeline=True`` swaps the stepping loop for the double-buffered
    ``AsyncStepper``: generation g+1's device evaluation is dispatched
    before generation g's archive ingest, hypervolume bookkeeping, and
    checkpoint write, which then overlap the in-flight device call. The RNG
    stream, archive, and every per-generation checkpoint stay bit-identical
    to the synchronous loop, so the two modes are freely interchangeable
    (even across a resume)."""

    def __init__(self, optimizer: OptimizerBase,
                 checkpoint_path: str | None = None,
                 ref_latency: float | None = None,
                 ref_throughput: float = 0.0,
                 async_pipeline: bool = False):
        self.optimizer = optimizer
        self.checkpoint_path = checkpoint_path
        self.ref_latency = ref_latency
        self.ref_throughput = ref_throughput
        self.async_pipeline = async_pipeline
        if checkpoint_path and (os.path.exists(checkpoint_path)
                                or os.path.exists(checkpoint_path + ".prev")):
            state, source = load_checkpoint_resilient(checkpoint_path)
            if state is None:
                _LOG.warning(f"[opt] no usable checkpoint at "
                             f"{checkpoint_path} (all candidates corrupt); "
                             f"starting fresh")
            else:
                if source != checkpoint_path:
                    _LOG.warning(f"[opt] resumed from fallback snapshot "
                                 f"{source}")
                for problem in check_version_stamp(state.get("versions"),
                                                  what="checkpoint"):
                    _LOG.warning(f"[opt] resume warning: {problem}")
                self.optimizer.load_state(state)

    def _after_generation(self, opt, meta, history, generations,
                          progress) -> None:
        if self.checkpoint_path:
            save_checkpoint(self.checkpoint_path, opt, meta)
        hv = None
        if self.ref_latency is not None:
            hv = opt.archive.hypervolume(self.ref_latency,
                                         self.ref_throughput)
            history.append(hv)
        msg = (f"[opt] gen {meta['generation']}/{generations} "
               f"evals={meta['n_evals']} "
               f"archive={len(opt.archive)}")
        if hv is not None:
            msg += f" hv={hv:.4g}"
        # progress=True keeps the classic stdout line (via the obs logging
        # root at INFO); progress=False still records it at DEBUG for
        # REPRO_LOG=debug runs.
        _LOG.log("info" if progress else "debug", msg)

    def run(self, generations: int, progress: bool = False) -> OptResult:
        opt = self.optimizer
        history = []
        history_start = opt.generation
        # SIGTERM/SIGINT set a pollable flag: the loop exits through its
        # normal checkpoint-flush path after the current generation, so a
        # preempted run resumes bit-identically (a second signal forces
        # KeyboardInterrupt).
        with graceful_shutdown() as stop:
            if self.async_pipeline:
                AsyncStepper(
                    opt, generations,
                    on_generation=lambda o, meta, ev: self._after_generation(
                        o, meta, history, generations, progress)).run(
                            stop=stop)
            else:
                while opt.generation < generations:
                    t0 = time.perf_counter()
                    n0 = opt.evaluator.n_evals
                    with _span("opt.generation", generation=opt.generation,
                               mode="sync"):
                        opt.step()
                        self._after_generation(opt, opt.snapshot_meta(),
                                               history, generations, progress)
                    dt = time.perf_counter() - t0
                    _metrics.histogram("opt.generation_s").observe(dt)
                    if dt > 0:
                        _metrics.histogram("opt.evals_per_s").observe(
                            (opt.evaluator.n_evals - n0) / dt)
                    if stop.requested():
                        break
            if stop.requested():
                _LOG.warning(f"[opt] shutdown at generation "
                             f"{opt.generation}/{generations}; checkpoint "
                             f"is current — rerun to resume")
        return OptResult(archive=opt.archive, n_evals=opt.evaluator.n_evals,
                         generations=opt.generation, history=history,
                         history_start=history_start)


def make_space(kind: str, **kw) -> SearchSpace:
    if kind == "adjacency":
        return AdjacencySpace(**kw)
    if kind == "parametric":
        return ParametricSpace(**kw)
    raise ValueError(f"unknown space {kind!r}; options: adjacency, parametric")


def make_optimizer(algo: str, space: SearchSpace,
                   evaluator: PopulationEvaluator, seed: int = 0,
                   **kw) -> OptimizerBase:
    try:
        cls = ALGORITHMS[algo]
    except KeyError:
        raise ValueError(f"unknown algorithm {algo!r}; options: "
                         f"{sorted(ALGORITHMS)}") from None
    return cls(space, evaluator, seed=seed, **kw)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.opt",
        description="Population-based multi-objective ICI design "
                    "optimization on the batched proxy engine.")
    p.add_argument("--space", choices=("adjacency", "parametric"),
                   default="adjacency")
    p.add_argument("--algo", choices=sorted(ALGORITHMS), default="nsga2")
    p.add_argument("--n-chiplets", type=int, default=32,
                   help="adjacency space: chiplet count")
    p.add_argument("--max-degree", type=int, default=8,
                   help="adjacency space: soft per-chiplet link cap")
    p.add_argument("--counts", type=str, default="16,36,64",
                   help="parametric space: comma-separated chiplet counts")
    p.add_argument("--traffic", type=str, default="random_uniform")
    p.add_argument("--routing", type=str, default="dijkstra_lowest_id")
    p.add_argument("--generations", type=int, default=20)
    p.add_argument("--pop-size", type=int, default=24)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--max-interposer-area", type=float, default=None)
    p.add_argument("--max-total-area", type=float, default=None)
    p.add_argument("--max-power", type=float, default=None)
    p.add_argument("--max-cost", type=float, default=None)
    p.add_argument("--host-path", action="store_true",
                   help="force the classic host evaluation path "
                        "(decode -> DesignPoint -> structure cache) instead "
                        "of the fused device genome pipeline")
    p.add_argument("--async", dest="async_pipeline", action="store_true",
                   help="double-buffered generation pipeline: dispatch the "
                        "next generation's device call before archiving / "
                        "checkpointing the current one (bit-identical "
                        "results, lower wall-clock)")
    p.add_argument("--checkpoint", type=str, default=None,
                   help="resume point, written after every generation")
    p.add_argument("--faults", action="store_true",
                   help="fault-aware search: evaluate every genome over a "
                        "batch of failure scenarios and optimize the "
                        "degraded (worst/expected) latency-throughput "
                        "front instead of the pristine one (adjacency "
                        "space, device path only)")
    p.add_argument("--fault-model", type=str, default="single",
                   help="fault scenario sampler: iid, region, single, "
                        "double, chiplet (see repro.faults.model)")
    p.add_argument("--fault-p", type=float, default=0.02,
                   help="iid model: per-link failure probability")
    p.add_argument("--fault-scenarios", type=int, default=16,
                   help="iid/region models: sampled scenario count")
    p.add_argument("--fault-seed", type=int, default=0,
                   help="fault sampler seed (independent of --seed)")
    p.add_argument("--fault-top-k", type=int, default=None,
                   help="single/double models: restrict enumeration to the "
                        "k longest-trace link slots")
    p.add_argument("--fault-mode", choices=("worst", "expected"),
                   default="worst",
                   help="robust objective: worst-case over scenarios or "
                        "scenario-weighted expectation")
    p.add_argument("--max-disconnect", type=float, default=0.0,
                   help="feasibility cap on the probability mass of "
                        "scenarios that disconnect any traffic")
    p.add_argument("--out", type=str, default=None,
                   help="write the final front as JSON rows")
    p.add_argument("--trace", type=str, nargs="?", const="opt_trace",
                   default=None, metavar="PREFIX",
                   help="enable full tracing and write <PREFIX>.trace.jsonl, "
                        "<PREFIX>.chrome.json (Perfetto-loadable), "
                        "<PREFIX>.metrics.json, and <PREFIX>.report.json "
                        "at the end of the run (default prefix: opt_trace)")
    p.add_argument("--quiet", action="store_true")
    args = p.parse_args(argv)

    if args.trace:
        enable_tracing()

    if args.space == "adjacency":
        space = make_space("adjacency", n_chiplets=args.n_chiplets,
                           max_degree=args.max_degree,
                           traffic_pattern=args.traffic,
                           routing=args.routing)
    else:
        counts = tuple(int(c) for c in args.counts.split(","))
        space = make_space("parametric", chiplet_counts=counts,
                           traffic_pattern=args.traffic,
                           routings=(args.routing,))
    budgets = Budgets(max_interposer_area=args.max_interposer_area,
                      max_total_area=args.max_total_area,
                      max_power=args.max_power, max_cost=args.max_cost)
    faults = None
    if args.faults:
        if args.space != "adjacency":
            p.error("--faults requires --space adjacency")
        if args.host_path:
            p.error("--faults requires the fused device path "
                    "(drop --host-path)")
        from ..faults.model import make_scenarios
        from ..faults.objectives import FaultSetup, RobustObjectives
        kw: dict = {}
        if args.fault_model == "iid":
            kw = {"p": args.fault_p, "n_scenarios": args.fault_scenarios,
                  "seed": args.fault_seed}
        elif args.fault_model == "region":
            kw = {"n_scenarios": args.fault_scenarios,
                  "seed": args.fault_seed}
        elif args.fault_model in ("single", "double") \
                and args.fault_top_k is not None:
            kw = {"top_k": args.fault_top_k}
        scenarios = make_scenarios(space, args.fault_model, **kw)
        faults = FaultSetup(
            scenarios=scenarios,
            objectives=RobustObjectives(
                mode=args.fault_mode,
                max_disconnect_prob=args.max_disconnect))
        _LOG.info(f"[opt] fault-aware search: model={args.fault_model} "
                  f"F={scenarios.n_scenarios} mode={args.fault_mode}")
    evaluator = PopulationEvaluator(
        space, budgets=budgets,
        device_path=False if args.host_path else None,
        faults=faults)
    size_kw = ({"batch_size": args.pop_size} if args.algo == "random"
               else {"n_chains": args.pop_size} if args.algo == "sa"
               else {"pop_size": args.pop_size})
    optimizer = make_optimizer(args.algo, space, evaluator, seed=args.seed,
                               **size_kw)
    runner = OptRunner(optimizer, checkpoint_path=args.checkpoint,
                       async_pipeline=args.async_pipeline)
    result = runner.run(args.generations, progress=not args.quiet)

    rows = result.to_rows(space)
    lvl = "debug" if args.quiet else "info"
    _LOG.log(lvl, f"[opt] {result.n_evals} evaluations, "
                  f"{len(result.archive)} points on the front:")
    for r in rows:
        _LOG.log(lvl,
                 f"   lat={r['latency']:8.2f} thr={r['throughput']:10.2f} "
                 f"area={r.get('interposer_area', float('nan')):8.1f} "
                 f"links={r.get('n_links', '-')}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=2)
            f.write("\n")
        _LOG.log(lvl, f"[opt] front written to {args.out}")
    if args.trace:
        from ..obs.report import dump_run, format_report
        summary = dump_run(args.trace)
        _LOG.log(lvl, format_report(summary))
        _LOG.log(lvl, f"[opt] trace written to {args.trace}.trace.jsonl / "
                      f"{args.trace}.chrome.json (open in Perfetto); "
                      f"report in {args.trace}.report.json")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
