"""Population-based multi-objective ICI design optimization (repro.opt).

The paper positions the proxies as "a cost function for optimization
algorithms"; this package is that consumer. Search spaces encode designs as
integer genomes (registered parametric topologies or PlaceIT-style free-form
adjacency), seeded vectorized operators vary whole populations, and every
generation is one batched, structure-cached proxy evaluation through
``DseEngine.evaluate_points``. A Pareto archive with a 2-D hypervolume
indicator and area/power/cost constraint masks tracks the front; the runner
checkpoints optimizer state after every generation and resumes
bit-identically.

``archive``/``operators`` are dependency-light and imported eagerly (the
sweep-side ``dse.pareto`` re-exports the front computation from here); the
engine-facing modules load lazily on first attribute access.
"""
from .archive import ArchiveEntry, ParetoArchive, hypervolume_2d, pareto_front
from .operators import mutate_genes, tournament_select, uniform_crossover

_LAZY = {
    "SearchSpace": "space", "ParametricSpace": "space",
    "AdjacencySpace": "space", "DEFAULT_TOPOLOGIES": "space",
    "Budgets": "algorithms", "PopulationEvaluator": "algorithms",
    "EvaluatedPopulation": "algorithms", "EvolutionarySearch": "algorithms",
    "SimulatedAnnealing": "algorithms", "RandomSearch": "algorithms",
    "ALGORITHMS": "algorithms", "nondominated_ranks": "algorithms",
    "crowding_distance": "algorithms",
    "OptRunner": "runner", "OptResult": "runner", "AsyncStepper": "runner",
    "make_space": "runner", "make_optimizer": "runner",
    "save_checkpoint": "runner", "load_checkpoint": "runner",
}

__all__ = [
    "ArchiveEntry", "ParetoArchive", "hypervolume_2d", "pareto_front",
    "mutate_genes", "tournament_select", "uniform_crossover",
    *sorted(_LAZY),
]


def __getattr__(name):
    if name in _LAZY:
        import importlib
        module = importlib.import_module(f".{_LAZY[name]}", __name__)
        value = getattr(module, name)
        globals()[name] = value
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
