"""Seeded, vectorized variation operators over integer genome populations.

Genomes are int64 arrays [P, G]; gene g takes values in
``range(cardinalities[g])`` (binary adjacency genes have cardinality 2).
Every operator draws from a caller-owned ``np.random.Generator``, so an
optimizer's whole trajectory is a pure function of its seed — the property
the checkpoint/resume story relies on.
"""
from __future__ import annotations

import numpy as np


def mutate_genes(genomes: np.ndarray, cardinalities: np.ndarray, rate: float,
                 rng: np.random.Generator) -> np.ndarray:
    """Per-gene resampling mutation over a whole population at once.

    Each gene mutates with probability ``rate``; a mutated gene is shifted by
    a uniform non-zero offset modulo its cardinality, so mutation always
    changes the gene (cardinality-1 genes never mutate)."""
    genomes = np.asarray(genomes, np.int64)
    card = np.asarray(cardinalities, np.int64)[None, :]
    mask = rng.random(genomes.shape) < rate
    # Draw against max(card, 2) so degenerate genes still consume one draw
    # per position (keeps the RNG stream independent of cardinalities).
    shift = rng.integers(1, np.maximum(card, 2), size=genomes.shape)
    mask &= card > 1
    return np.where(mask, (genomes + shift) % np.maximum(card, 1), genomes)


def uniform_crossover(parents_a: np.ndarray, parents_b: np.ndarray,
                      rng: np.random.Generator, p: float = 0.5) -> np.ndarray:
    """Gene-wise uniform crossover of two parent populations [P, G]."""
    a = np.asarray(parents_a, np.int64)
    b = np.asarray(parents_b, np.int64)
    pick = rng.random(a.shape) < p
    return np.where(pick, a, b)


def tournament_select(scores: np.ndarray, n_select: int,
                      rng: np.random.Generator, k: int = 2) -> np.ndarray:
    """k-way tournament selection: returns [n_select] indices into the
    population; lower score wins (ties break toward the first drawn
    candidate)."""
    scores = np.asarray(scores, np.float64)
    cand = rng.integers(0, len(scores), size=(n_select, k))
    winner = np.argmin(scores[cand], axis=1)
    return cand[np.arange(n_select), winner]
