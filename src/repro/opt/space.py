"""Genome ⇄ DesignPoint encodings for the design-space optimizers.

Two search spaces over the paper's design space:

* ``ParametricSpace`` — categorical genome over the registered parametric
  topologies × chiplet counts × routings (+ an SHG bits gene, active only
  when the topology gene decodes to "shg");
* ``AdjacencySpace`` — PlaceIT-style free-form topologies: one bit per
  unordered chiplet pair, decoded through the ``custom`` topology entry's
  explicit link list, with deterministic validity *repair* (degree capping +
  connectivity) so every genome decodes to a buildable, connected design.

Genomes are int64 arrays [P, G]; ``repair`` is a pure function of the genome
(no RNG), which the checkpoint/resume story relies on.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field

import numpy as np

# Guards the lazy jit-scan build on AdjacencySpace instances (dataclass
# instances can't carry their own lock as a field without breaking eq/
# repr; builds are rare, so one module lock costs nothing).
_CAP_FN_LOCK = threading.Lock()

from ..core.design import Packaging, Technology
from ..dse.sweep import DesignPoint
from ..topologies.grid import grid_dims

# Parametric topologies valid for any chiplet count (hypercube needs powers
# of two; router topologies double the node count — both opt-in).
DEFAULT_TOPOLOGIES = (
    "mesh", "torus", "folded_torus", "flattened_butterfly", "shg",
    "sid_mesh", "octamesh", "octatorus", "folded_octatorus",
    "hexamesh", "hexatorus", "folded_hexatorus",
)
_ROUTER_TOPOS = ("double_butterfly", "butterdonut", "cluscross", "kite")


def _pow2_bucket(n: int) -> int:
    """Power-of-two padding bucket (>= 8) for the degree-cap candidate list.
    Kept pow2 here regardless of how ``dse.genomes.node_bucket`` pads node
    counts: candidate counts vary wildly between repair calls, and a coarse
    doubling ladder keeps the jitted scan's compile cache small."""
    b = 8
    while b < n:
        b *= 2
    return b


class SearchSpace:
    """Base interface: integer genomes with per-gene cardinalities."""

    genome_length: int
    cardinalities: np.ndarray     # int64 [G]
    max_nodes: int                # padded node count for the proxy batch

    def sample(self, rng: np.random.Generator, size: int) -> np.ndarray:
        """[size, G] valid (already repaired) genomes."""
        raise NotImplementedError

    def repair(self, genomes: np.ndarray) -> np.ndarray:
        """Deterministically map arbitrary genomes to valid ones — a pure
        function of the genome, so optimizer trajectories replay exactly."""
        raise NotImplementedError

    def decode_one(self, genome: np.ndarray, index: int) -> DesignPoint:
        raise NotImplementedError

    def decode(self, genomes: np.ndarray,
               start_index: int = 0) -> list[DesignPoint]:
        return [self.decode_one(g, start_index + i)
                for i, g in enumerate(np.asarray(genomes, np.int64))]

    def describe(self, genome: np.ndarray) -> dict:
        """Human-readable summary of one genome (for result files)."""
        pt = self.decode_one(np.asarray(genome, np.int64), 0)
        return {"topology": pt.topology, "n_chiplets": pt.n_chiplets,
                "routing": pt.routing, "shg_bits": pt.shg_bits,
                "n_links": len(pt.links)}


@dataclass
class ParametricSpace(SearchSpace):
    """Genome = [topology, chiplet-count, routing, shg-bits] categorical
    indices over the registered generators."""

    topologies: tuple = DEFAULT_TOPOLOGIES
    chiplet_counts: tuple = (16, 36, 64)
    routings: tuple = ("dijkstra_lowest_id",)
    shg_bits_choices: tuple = tuple(range(16))
    traffic_pattern: str = "random_uniform"
    seed: int = 0
    packaging: Packaging = field(default_factory=Packaging)
    technology: Technology = field(default_factory=Technology)

    def __post_init__(self):
        self.cardinalities = np.asarray(
            [len(self.topologies), len(self.chiplet_counts),
             len(self.routings), max(len(self.shg_bits_choices), 1)],
            np.int64)
        self.genome_length = 4
        mult = 2 if any(t in _ROUTER_TOPOS for t in self.topologies) else 1
        self.max_nodes = max(self.chiplet_counts) * mult

    def sample(self, rng: np.random.Generator, size: int) -> np.ndarray:
        return rng.integers(0, self.cardinalities[None, :],
                            size=(size, self.genome_length))

    def repair(self, genomes: np.ndarray) -> np.ndarray:
        return np.asarray(genomes, np.int64) % self.cardinalities[None, :]

    def decode_one(self, genome: np.ndarray, index: int) -> DesignPoint:
        topo_i, count_i, routing_i, bits_i = (int(v) for v in genome)
        topology = self.topologies[topo_i]
        n = self.chiplet_counts[count_i]
        bits = 0
        if topology == "shg":
            bits = int(self.shg_bits_choices[bits_i])
            r, c = grid_dims(n)
            bits %= 2 ** (r + c - 4)     # clamp to the grid's parametrization
        return DesignPoint(
            index=index, topology=topology, n_chiplets=n,
            traffic_pattern=self.traffic_pattern,
            routing=self.routings[routing_i], seed=self.seed, shg_bits=bits,
            packaging=self.packaging, technology=self.technology)

    def enumerate_genomes(self) -> np.ndarray:
        """Every *distinct* design in the space (the exhaustive-sweep
        baseline). The SHG-bits gene is inert for non-shg topologies, so it
        is enumerated only where it changes the decoded design — a cartesian
        product over all four genes would hand the sweep mostly duplicate
        evaluations."""
        rows = []
        for ti, topo in enumerate(self.topologies):
            for ci, n in enumerate(self.chiplet_counts):
                if topo == "shg":
                    # decode clamps the chosen bits *value* to the grid's
                    # parametrization; emit one index per distinct clamped
                    # value so the enumeration never repeats a design
                    r, c = grid_dims(n)
                    mod = 2 ** (r + c - 4)
                    seen_vals: set[int] = set()
                    bits_range = []
                    for bi, choice in enumerate(self.shg_bits_choices):
                        v = int(choice) % mod
                        if v not in seen_vals:
                            seen_vals.add(v)
                            bits_range.append(bi)
                else:
                    bits_range = [0]
                for ri in range(len(self.routings)):
                    for bi in bits_range:
                        rows.append((ti, ci, ri, bi))
        return np.asarray(rows, np.int64)


@dataclass
class AdjacencySpace(SearchSpace):
    """Free-form topology genome: bit g(u,v) = link between chiplets u < v.

    ``repair`` makes any bit-vector a valid design, deterministically:

    1. degree cap — scan set bits from the highest pair index down and clear
       any whose endpoints both stay connected but exceed ``max_degree``;
    2. connectivity — union components by adding a link between each
       component's minimum-degree chiplet (ties toward the lowest index).
       A join may exceed the cap by one when a component is saturated;
       the cap is a soft area-control bound, the chiplet radix follows the
       realized degree.
    """

    n_chiplets: int = 32
    max_degree: int = 8
    init_density: float | None = None   # default: target max_degree/2 average
    traffic_pattern: str = "random_uniform"
    routing: str = "dijkstra_lowest_id"
    seed: int = 0
    packaging: Packaging = field(default_factory=Packaging)
    technology: Technology = field(default_factory=Technology)

    def __post_init__(self):
        n = self.n_chiplets
        iu = np.triu_indices(n, k=1)
        self.pair_u = iu[0].astype(np.int64)
        self.pair_v = iu[1].astype(np.int64)
        self.genome_length = len(self.pair_u)
        self.cardinalities = np.full(self.genome_length, 2, np.int64)
        self.max_nodes = n
        # Incidence matrix [G, n]: degrees of a population are one matmul
        # (kept in float32 — a BLAS sgemm beats the int64 path ~20x, and
        # degree counts ≤ n-1 are exactly representable).
        self._incidence = np.zeros((self.genome_length, n), np.float32)
        self._incidence[np.arange(self.genome_length), self.pair_u] = 1
        self._incidence[np.arange(self.genome_length), self.pair_v] = 1
        if self.init_density is None:
            self.init_density = min(1.0, 0.5 * self.max_degree / max(n - 1, 1))

    def sample(self, rng: np.random.Generator, size: int) -> np.ndarray:
        bits = (rng.random((size, self.genome_length))
                < self.init_density).astype(np.int64)
        return self.repair(bits)

    def degrees(self, genomes: np.ndarray) -> np.ndarray:
        """Vertex degrees [P, n] of a population of bit genomes."""
        bits = np.asarray(genomes, np.int64) % 2
        return (bits.astype(np.float32) @ self._incidence).astype(np.int64)

    def repair(self, genomes: np.ndarray) -> np.ndarray:
        """Vectorized over the whole population: the degree-cap pass is one
        descending scan over gene columns ([P] updates per column), the
        connectivity pass replicates ``_repair_one``'s union-find root
        labeling with pointer-doubling gathers and merges every genome's
        components in lockstep. Bit-identical to mapping ``_repair_one`` over
        the rows (asserted in tests/test_device_path.py)."""
        bits = np.asarray(genomes, np.int64) % 2
        P, G = bits.shape
        if P == 0:
            return bits
        n, maxd = self.n_chiplets, self.max_degree
        pu, pv = self.pair_u, self.pair_v
        bits = bits.copy()
        deg = self.degrees(bits)

        # 1. degree cap, dropping from the highest pair index down. Dropping
        # only ever *decrements* degrees, so a vertex not over the cap at
        # the start never goes over later. The scan is loop-carried (each
        # drop changes the degrees later columns see), so it runs as a
        # jitted lax.fori_loop over columns — integer ops, bit-identical to
        # the Python scan, and off the optimizer's critical path even when
        # crossover floods the population with over-cap children.
        over = deg > maxd
        if over.any():
            # Degrees only ever decrease, so the scan can touch exactly the
            # columns that are set somewhere AND incident to an initially
            # over-cap vertex. The candidate list (descending, padded to a
            # power-of-two bucket with a no-op sentinel so the jit cache
            # stays small) drives the compiled loop.
            cand = ((bits == 1) &
                    (over[:, pu] | over[:, pv])).any(axis=0)
            idx = np.nonzero(cand)[0][::-1].astype(np.int32)
            bucket = _pow2_bucket(len(idx))
            idx = np.concatenate(
                [idx, np.full(bucket - len(idx), G, np.int32)])
            bt = np.concatenate(
                [np.ascontiguousarray(bits.T, np.int32),
                 np.zeros((1, P), np.int32)])        # sentinel row g = G
            b2, d2 = self._degree_cap_fn()(
                bt, np.ascontiguousarray(deg.T, np.int32),
                np.asarray(idx, np.int32))
            bits = np.asarray(b2, np.int64)[:G].T.copy()
            deg = np.asarray(d2, np.int64).T.copy()

        # 2. connectivity — only for genomes that need it. Connected ⟺
        # every vertex reachable from vertex 0. The frontier expansion runs
        # edge-wise through the incidence matrix — activate every set gene
        # with a reached endpoint, scatter back to both endpoints via one
        # sgemm — so the transient stays [P, G] (the genome's own footprint)
        # instead of a dense [P, n, n] adjacency stack; already-connected
        # genomes (the steady-state majority after variation) skip the
        # union-find scan entirely.
        bf = (bits == 1).astype(np.float32)
        reach = np.zeros((P, n), np.float32)
        reach[:, 0] = 1.0
        while True:
            active = bf * (reach[:, pu] + reach[:, pv])
            new = np.minimum(reach + active @ self._incidence, 1.0)
            if np.array_equal(new, reach):
                break
            reach = new
        bad = np.nonzero(reach.min(axis=1) == 0)[0]
        if len(bad):
            bits[bad] = self._connect_batch(bits[bad], deg[bad])
        return bits

    def _degree_cap_fn(self):
        """Jit-compiled descending degree-cap scan (built lazily, cached on
        the space): one XLA loop step per *candidate* column, with [P]-wide
        integer updates. The drop predicate makes sentinel/settled columns
        no-ops, so the packed scan is bit-identical to the full sequential
        reference."""
        fn = getattr(self, "_cap_fn", None)
        if fn is None:
            with _CAP_FN_LOCK:
                return self._degree_cap_fn_build()
        return fn

    def _degree_cap_fn_build(self):
        # Under _CAP_FN_LOCK: concurrent server jobs repairing on one
        # shared space build the scan once (re-check after acquisition).
        fn = getattr(self, "_cap_fn", None)
        if fn is None:
            import jax
            import jax.numpy as jnp

            # endpoint tables extended with a sentinel entry for g = G
            pu = jnp.asarray(np.concatenate([self.pair_u, [0]]), jnp.int32)
            pv = jnp.asarray(np.concatenate([self.pair_v, [0]]), jnp.int32)
            maxd = self.max_degree

            @jax.jit
            def cap(bits_t, deg_t, idx):
                # gene-major layout [G+1, P] / [n, P]: each column update
                # is one contiguous row (a cheap dynamic-slice store)
                def body(i, state):
                    b, d = state
                    g = idx[i]
                    u, v = pu[g], pv[g]
                    drop = ((b[g] == 1) & ((d[u] > maxd) | (d[v] > maxd))
                            ).astype(jnp.int32)
                    b = b.at[g].add(-drop)
                    d = d.at[u].add(-drop)
                    d = d.at[v].add(-drop)
                    return b, d

                return jax.lax.fori_loop(0, idx.shape[0], body,
                                         (bits_t, deg_t))

            fn = self._cap_fn = cap
        return fn

    def _connect_batch(self, bits: np.ndarray, deg: np.ndarray) -> np.ndarray:
        """Connectivity repair for a (sub)population of degree-capped
        genomes, replicating the union-find root labels of ``_repair_one``:
        surviving genes are processed in ascending order, and the invariant
        "parent is fully path-compressed before each union" makes one
        pointer-doubling gather per gene sufficient. Components are then
        unioned in lockstep, each genome joining its two lowest-rooted
        components at their minimum-degree (lowest-index) chiplets — the
        same deterministic rule as the sequential pass."""
        P, _ = bits.shape
        n = self.n_chiplets
        pu, pv = self.pair_u, self.pair_v
        rows = np.arange(P)
        parent = np.tile(np.arange(n), (P, 1))
        for g in np.nonzero(bits.any(axis=0))[0]:
            parent = parent[rows[:, None], parent]
            ru = parent[rows, pu[g]]
            rv = parent[rows, pv[g]]
            m = (bits[:, g] == 1) & (ru != rv)
            parent[rows[m], ru[m]] = rv[m]
        roots = parent[rows[:, None], parent]

        score_idx = np.arange(n)[None, :]
        big = np.int64(n * n + n)
        while True:
            present = np.zeros((P, n), bool)
            present[rows[:, None], roots] = True
            todo = present.sum(axis=1) > 1
            if not todo.any():
                break
            first = present.argmax(axis=1)
            p2 = present.copy()
            p2[rows, first] = False
            second = p2.argmax(axis=1)
            score = deg * n + score_idx     # orders by (degree, index)
            a = np.where(roots == first[:, None], score, big).argmin(axis=1)
            b = np.where(roots == second[:, None], score, big).argmin(axis=1)
            u = np.minimum(a, b)
            v = np.maximum(a, b)
            g = u * (2 * n - u - 1) // 2 + (v - u - 1)
            t = rows[todo]
            bits[t, g[todo]] = 1
            deg[t, u[todo]] += 1
            deg[t, v[todo]] += 1
            roots = np.where(todo[:, None] & (roots == second[:, None]),
                             first[:, None], roots)
        return bits

    def _repair_one(self, bits: np.ndarray) -> np.ndarray:
        """Sequential single-genome reference for ``repair`` (the oracle the
        vectorized path is tested against)."""
        n, maxd = self.n_chiplets, self.max_degree
        bits = bits.copy()
        deg = np.zeros(n, np.int64)
        set_idx = np.nonzero(bits)[0]
        np.add.at(deg, self.pair_u[set_idx], 1)
        np.add.at(deg, self.pair_v[set_idx], 1)
        # 1. degree cap, dropping from the highest pair index down
        for g in set_idx[::-1]:
            u, v = self.pair_u[g], self.pair_v[g]
            if deg[u] > maxd or deg[v] > maxd:
                bits[g] = 0
                deg[u] -= 1
                deg[v] -= 1
        # 2. connectivity via union-find over the surviving links
        parent = np.arange(n)

        def find(x):
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        for g in np.nonzero(bits)[0]:
            ru, rv = find(self.pair_u[g]), find(self.pair_v[g])
            if ru != rv:
                parent[ru] = rv
        # repro-lint: allow[axis-loop] sequential reference oracle (vectorized twin in repair())
        roots = np.asarray([find(i) for i in range(n)])
        comp_ids = np.unique(roots)
        while len(comp_ids) > 1:
            # connect the two lexicographically-first components at their
            # minimum-degree chiplets (deterministic, no RNG)
            members_a = np.nonzero(roots == comp_ids[0])[0]
            members_b = np.nonzero(roots == comp_ids[1])[0]
            a = members_a[np.argmin(deg[members_a])]
            b = members_b[np.argmin(deg[members_b])]
            u, v = (a, b) if a < b else (b, a)
            g = self._pair_index(u, v)
            bits[g] = 1
            deg[u] += 1
            deg[v] += 1
            roots[members_b] = comp_ids[0]
            comp_ids = np.unique(roots)
        return bits

    def _pair_index(self, u: int, v: int) -> int:
        """Index of pair (u, v), u < v, in the upper-triangular flattening."""
        n = self.n_chiplets
        return int(u * (2 * n - u - 1) // 2 + (v - u - 1))

    def edges_of(self, bits: np.ndarray) -> tuple:
        set_idx = np.nonzero(np.asarray(bits, np.int64))[0]
        return tuple((int(self.pair_u[g]), int(self.pair_v[g]))
                     for g in set_idx)

    def decode_one(self, genome: np.ndarray, index: int) -> DesignPoint:
        return DesignPoint(
            index=index, topology="custom", n_chiplets=self.n_chiplets,
            traffic_pattern=self.traffic_pattern, routing=self.routing,
            seed=self.seed, shg_bits=0, packaging=self.packaging,
            technology=self.technology, links=self.edges_of(genome))
