"""The search service: many concurrent optimizer jobs, one device (ISSUE 10).

``SearchService`` runs a single scheduler thread that drives every
admitted job cooperatively through the ``OptimizerBase``
``begin_step``/``finish_step`` split. Each round it collects one pending
population from every running job, groups them by search-space identity,
concatenates each group into one **mega-batch**, and dispatches it
through the shared ``DseEngine`` — so a hundred small jobs fill the
device the way one large job would, through the same pow2 population
buckets (no new compilations: the fused eval is row-independent, and
``bucket_population`` padding is exact, so a row's metrics are
bit-identical at any batch size or offset). Results are sliced back
per job and folded in through each job's own ``PopulationEvaluator``
(budget masks, non-finite quarantine, eval counting — the exact solo
path), so **every job's archive, RNG stream, and checkpoints are
bit-identical to the same spec run solo** (``job.run_spec_solo``;
asserted in tests/test_serve.py).

Robustness model:

* **Fault isolation** — a mega-batch dispatch/materialization failure
  (including the ``chaos_fail_generation`` injection hook) falls back to
  per-job solo dispatches with bounded retries; only the job whose own
  dispatch keeps failing is marked FAILED. Batch-mates re-evaluate solo
  to the same bits. Non-finite rows quarantine per job slice.
* **Admission control + backpressure** — ``submit`` rejects with an
  explicit reason (``AdmissionError.reason``) once ``max_queued`` specs
  are waiting, when the service is draining, when the spec is invalid,
  or when the tenant's eval budget is already spent; sheds are counted
  per reason on ``serve.shed``. At most ``max_jobs`` jobs run at once;
  the rest queue.
* **Budgets and deadlines** — per-job ``max_evals`` stops a job early
  through the same pre-dispatch check the solo reference applies (the
  stopped front is still bit-identical); per-tenant budgets are enforced
  mid-run (the offending job fails, the tenant's other jobs keep their
  finished evals); per-job deadlines are monotonic-clock walls checked
  between generations.
* **Drain/resume** — ``drain()`` (the CLI wires it to SIGTERM) stops
  admission, finishes the in-flight round, snapshots every running job
  through the format-2 checksummed checkpoints, and writes an atomic
  manifest; a service restarted on the same ``state_dir`` resumes every
  job bit-identically. Per-generation checkpoints (``ckpt_every``) make
  even a SIGKILL resumable.
* **Observability** — queue/running gauges, mega-batch occupancy and
  round-latency histograms, shed/retry/fault counters, and spans on the
  scheduler round through ``repro.obs``.

Scope: jobs evaluate through the fused device genome path or the host
``evaluate_points`` path (not co-batched, still isolated); fault-grid
(``FaultSetup``) jobs are not served — run those through ``repro.opt``.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time

import numpy as np

from ..core.reports import ReportArrays
from ..dse.engine import DseEngine
from ..dse.genomes import GenomeEvalResult, PendingGenomeEval
from ..faults.harness import BackendChaosError, call_with_retry
from ..obs import metrics as _metrics
from ..obs.log import get_logger
from ..obs.trace import span as _span
from ..opt.algorithms import Budgets, PopulationEvaluator
from ..opt.runner import load_checkpoint_resilient, save_checkpoint
from ..utils import env as _env
from . import job as _job
from .job import (DONE, FAILED, QUEUED, RUNNING, SUSPENDED, TERMINAL, Job,
                  JobSpec, eval_budget_reached, front_rows, write_front)

log = get_logger("repro.serve")


class AdmissionError(RuntimeError):
    """A submission the service refused, with a machine-readable reason
    (``queue_full`` | ``draining`` | ``duplicate`` | ``bad_spec`` |
    ``tenant_budget`` | ``stopped``)."""

    def __init__(self, reason: str, detail: str = ""):
        super().__init__(f"job rejected ({reason})"
                         + (f": {detail}" if detail else ""))
        self.reason = reason


def _slice_result(res: GenomeEvalResult, sl: slice) -> GenomeEvalResult:
    rep = {f.name: getattr(res.reports, f.name)
           for f in dataclasses.fields(res.reports)}
    return GenomeEvalResult(
        latency=res.latency[sl], throughput=res.throughput[sl],
        reports=ReportArrays(**{k: (None if v is None else v[sl])
                                for k, v in rep.items()}))


class _EvalRequest:
    """One job's pending population inside the current scheduler round."""

    def __init__(self, service, job: Job, space, genomes: np.ndarray):
        self.service = service
        self.job = job
        self.space = space
        self.genomes = genomes
        self.fetch = None            # installed by _flush_round

    def result(self) -> GenomeEvalResult:
        if self.fetch is None:
            raise RuntimeError("evaluation round was never flushed")
        return self.fetch()


class CoBatchEngine:
    """The engine facade each job's ``PopulationEvaluator`` sees.

    ``evaluate_genomes_async`` does not touch the device — it parks the
    population in the scheduler's current round and returns a pending
    handle; the scheduler later dispatches all parked populations as
    grouped mega-batches and the handle resolves to this job's row
    slice. Everything else delegates to the shared real engine, so
    host-path spaces and capability checks behave exactly as solo."""

    def __init__(self, service: "SearchService", job: Job):
        self._service = service
        self._job = job

    def supports_genomes(self, space) -> bool:
        return self._service.engine.supports_genomes(space)

    def supports_faults(self, space) -> bool:
        return False        # fault-grid jobs are out of serve's scope

    def evaluate_genomes_async(self, space, genomes) -> PendingGenomeEval:
        req = self._service._enqueue(self._job, space, genomes)
        return PendingGenomeEval(req.result)

    def evaluate_genomes(self, space, genomes) -> GenomeEvalResult:
        return self.evaluate_genomes_async(space, genomes).result()

    def evaluate_points(self, points, **kw):
        return self._service.engine.evaluate_points(points, **kw)


class SearchService:
    """A persistent, fault-isolated multi-job search scheduler.

    In-process use::

        svc = SearchService()
        svc.submit(JobSpec(job_id="a", algo="nsga2", generations=8))
        job = svc.wait("a")
        rows = job.result_rows      # bit-identical to run_spec_solo

    ``python -m repro.serve`` wraps this with a jobs file, SIGTERM
    drain, and an optional HTTP front-end.
    """

    def __init__(self, engine: DseEngine | None = None,
                 state_dir: str | None = None,
                 max_jobs: int | None = None,
                 max_queued: int | None = None,
                 tenant_budgets: dict | None = None,
                 retries: int | None = None,
                 default_deadline_s: float | None = None,
                 ckpt_every: int | None = None):
        self.engine = engine if engine is not None else DseEngine()
        self.state_dir = state_dir
        self.max_jobs = (max_jobs if max_jobs is not None
                         else _env.get_int("REPRO_SERVE_MAX_JOBS"))
        self.max_queued = (max_queued if max_queued is not None
                           else _env.get_int("REPRO_SERVE_MAX_QUEUED"))
        self.tenant_budgets = dict(tenant_budgets or {})
        self.retries = (retries if retries is not None
                        else _env.get_int("REPRO_SERVE_RETRIES"))
        if default_deadline_s is None:
            d = _env.get_int("REPRO_SERVE_DEADLINE_S")
            default_deadline_s = float(d) if d > 0 else None
        self.default_deadline_s = default_deadline_s
        self.ckpt_every = (ckpt_every if ckpt_every is not None
                           else _env.get_int("REPRO_SERVE_CKPT_EVERY"))

        self._lock = threading.RLock()
        self._wake = threading.Condition(self._lock)
        self._jobs: dict[str, Job] = {}
        self._queue: list[Job] = []
        self._running: list[Job] = []
        self._tenant_spent: dict[str, int] = {}
        self._round: list[_EvalRequest] = []
        self._spaces: dict[tuple, object] = {}
        self._draining = False
        self._stopped = False
        self._thread: threading.Thread | None = None
        self._rounds = 0
        if state_dir:
            os.makedirs(state_dir, exist_ok=True)
            self._load_state_dir()

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "SearchService":
        with self._lock:
            if self._stopped:
                raise RuntimeError("service already drained/stopped")
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._loop, name="repro-serve-scheduler",
                    daemon=True)
                self._thread.start()
        return self

    def __enter__(self) -> "SearchService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.drain()

    def drain(self, timeout_s: float | None = None) -> None:
        """Stop admission, finish the in-flight round, checkpoint every
        running job (state ``suspended``), write the manifest, and stop
        the scheduler thread. Idempotent."""
        if timeout_s is None:
            timeout_s = float(_env.get_int("REPRO_SERVE_DRAIN_TIMEOUT_S"))
        with self._lock:
            self._draining = True
            thread = self._thread
            self._wake.notify_all()
        if thread is not None:
            thread.join(timeout=timeout_s)
            if thread.is_alive():
                log.warning("[serve] drain timed out; scheduler thread "
                            "still busy (daemon, will not block exit)")
        with self._lock:
            if self._thread is None and not self._stopped:
                # never started: suspend queued jobs directly
                self._suspend_all()
                self._stopped = True

    # -- admission ----------------------------------------------------------
    def submit(self, spec: JobSpec, auto_start: bool = True) -> str:
        """Admit one job spec (auto-starting the scheduler), or raise
        ``AdmissionError`` with an explicit shed reason.
        ``auto_start=False`` only parks the spec — the queue drains once
        ``start()`` runs (pre-loading, backpressure tests)."""
        with self._lock:
            reason, detail = self._admission_check(spec)
            if reason is not None:
                _metrics.counter("serve.shed", reason=reason).inc()
                log.warning(f"[serve] shed job {spec.job_id!r}: {reason} "
                            f"{detail}")
                raise AdmissionError(reason, detail)
            job = Job(spec)
            self._jobs[spec.job_id] = job
            self._queue.append(job)
            self._write_manifest()
            self._wake.notify_all()
        if auto_start:
            self.start()
        return spec.job_id

    def _admission_check(self, spec: JobSpec) -> tuple[str | None, str]:
        if self._stopped:
            return "stopped", "service already drained"
        if self._draining:
            return "draining", "service is draining"
        try:
            spec.validate()
        except ValueError as err:
            return "bad_spec", str(err)
        if spec.job_id in self._jobs:
            return "duplicate", f"job id {spec.job_id!r} already submitted"
        if len(self._queue) >= self.max_queued:
            return "queue_full", (f"{len(self._queue)} jobs queued "
                                  f"(max_queued={self.max_queued})")
        budget = self.tenant_budgets.get(spec.tenant)
        if budget is not None \
                and self._tenant_spent.get(spec.tenant, 0) >= budget:
            return "tenant_budget", (f"tenant {spec.tenant!r} spent "
                                     f"{self._tenant_spent[spec.tenant]} "
                                     f"of {budget} evals")
        return None, ""

    # -- introspection ------------------------------------------------------
    def job(self, job_id: str) -> Job:
        with self._lock:
            return self._jobs[job_id]

    def jobs(self) -> list[Job]:
        with self._lock:
            return list(self._jobs.values())

    def wait(self, job_id: str, timeout_s: float | None = None) -> Job:
        job = self.job(job_id)
        if not job.done_event.wait(timeout_s):
            raise TimeoutError(f"job {job_id!r} still "
                               f"{job.status} after {timeout_s}s")
        return job

    def wait_all(self, timeout_s: float | None = None) -> list[Job]:
        deadline = (None if timeout_s is None
                    else time.monotonic() + timeout_s)
        for job in self.jobs():
            left = (None if deadline is None
                    else max(0.0, deadline - time.monotonic()))
            self.wait(job.job_id, left)
        return self.jobs()

    def stats(self) -> dict:
        with self._lock:
            by_status: dict[str, int] = {}
            for job in self._jobs.values():
                by_status[job.status] = by_status.get(job.status, 0) + 1
            return {"queue_depth": len(self._queue),
                    "running": len(self._running),
                    "jobs": by_status,
                    "rounds": self._rounds,
                    "tenant_spent": dict(self._tenant_spent),
                    "evals_total": sum(j.n_evals
                                       for j in self._jobs.values()),
                    "draining": self._draining}

    # -- scheduler ----------------------------------------------------------
    def _loop(self) -> None:
        while True:
            with self._lock:
                self._admit_locked()
                running = list(self._running)
                draining = self._draining
                _metrics.gauge("serve.queue_depth").set(len(self._queue))
                _metrics.gauge("serve.running").set(len(running))
                if draining:
                    self._suspend_all()
                    self._stopped = True
                    self._wake.notify_all()
                    return
                if not running:
                    self._wake.wait(timeout=0.1)
                    continue
            t0 = time.perf_counter()
            with _span("serve.round", jobs=len(running)):
                self._run_round(running)
            dt = time.perf_counter() - t0
            with self._lock:
                self._rounds += 1
            _metrics.histogram("serve.round_s").observe(dt)

    def _admit_locked(self) -> None:
        while self._queue and len(self._running) < self.max_jobs:
            job = self._queue.pop(0)
            job.status = RUNNING
            self._running.append(job)

    def _run_round(self, running: list[Job]) -> None:
        """One generation step for every running job: pre-checks and
        dispatch for all, then one grouped mega-dispatch, then ingest.
        A job admitted during the round simply joins the next one."""
        dispatched: list[tuple[Job, object]] = []
        for job in running:
            pending = self._begin_job_step(job)
            if pending is not None:
                dispatched.append((job, pending))
        self._flush_round()
        for job, pending in dispatched:
            self._finish_job_step(job, pending)

    def _begin_job_step(self, job: Job):
        """Pre-dispatch checks + ``begin_step`` + dispatch. Returns the
        pending population eval, or None when the job reached a terminal
        state instead."""
        try:
            if job.optimizer is None:
                self._start_job(job)
            now = time.monotonic()
            if job.deadline_at is not None and now > job.deadline_at:
                self._fail(job, "deadline",
                           f"exceeded {job.spec.deadline_s or self.default_deadline_s}s")
                return None
            if job.finished():
                self._complete(job)
                return None
            tenant = job.spec.tenant
            budget = self.tenant_budgets.get(tenant)
            if budget is not None and (self._tenant_spent.get(tenant, 0)
                                       + job.spec.pop_size) > budget:
                self._fail(job, "tenant_budget",
                           f"tenant {tenant!r} budget {budget} evals")
                return None
            job._gen_t0 = time.perf_counter()
            genomes = job.optimizer.begin_step()
            pending = job.optimizer.evaluator.dispatch(genomes)
            self._tenant_spent[tenant] = (
                self._tenant_spent.get(tenant, 0) + len(genomes))
            return pending
        except Exception as err:  # noqa: BLE001 - isolate per job
            self._fail(job, "error", f"{type(err).__name__}: {err}")
            return None

    def _start_job(self, job: Job) -> None:
        job.space = self._space_for(job.spec)
        evaluator = PopulationEvaluator(job.space,
                                        engine=CoBatchEngine(self, job),
                                        budgets=Budgets(**job.spec.budgets))
        job.optimizer = _job.make_job_optimizer(job.spec, job.space,
                                                evaluator)
        if job.resume_state is not None:
            job.optimizer.load_state(job.resume_state)
            job.resume_state = None
            # restarted server: the resumed evals count against the
            # tenant's budget exactly as they did pre-crash
            tenant = job.spec.tenant
            self._tenant_spent[tenant] = (
                self._tenant_spent.get(tenant, 0)
                + job.optimizer.evaluator.n_evals)
        job.started_at = time.monotonic()
        deadline = (job.spec.deadline_s if job.spec.deadline_s is not None
                    else self.default_deadline_s)
        if deadline:
            job.deadline_at = job.started_at + float(deadline)

    def _space_for(self, spec: JobSpec):
        """One shared space instance per canonical spec — the co-batching
        unit: identical specs share one device pipeline and jit cache
        (spaces are deterministic, stateless functions of their params,
        so sharing cannot couple jobs)."""
        key = spec.space_key()
        space = self._spaces.get(key)
        if space is None:
            space = self._spaces[key] = _job.make_job_space(spec)
        return space

    # -- the co-batching round ---------------------------------------------
    def _enqueue(self, job: Job, space, genomes: np.ndarray) -> _EvalRequest:
        req = _EvalRequest(self, job, space, np.asarray(genomes, np.int64))
        self._round.append(req)
        return req

    def _chaos_due(self, job: Job) -> bool:
        cg = job.spec.chaos_fail_generation
        return (cg is not None and job.optimizer is not None
                and job.optimizer.generation == cg)

    def _maybe_chaos(self, job: Job) -> None:
        if self._chaos_due(job):
            raise BackendChaosError(
                f"job {job.job_id!r} chaos-failed at generation "
                f"{job.optimizer.generation} (chaos_fail_generation)")

    def _flush_round(self) -> None:
        """Dispatch every parked population: group by space identity,
        concatenate each group into one mega-batch, install per-request
        fetchers that slice this job's rows back out. A group whose mega
        dispatch fails (or that contains a chaos-armed job) degrades to
        per-job solo dispatches — bit-identical rows, isolated failures."""
        parked, self._round = self._round, []
        groups: dict[int, list[_EvalRequest]] = {}
        for req in parked:
            groups.setdefault(id(req.space), []).append(req)
        for reqs in groups.values():
            total = sum(len(r.genomes) for r in reqs)
            _metrics.histogram("serve.batch_occupancy").observe(total)
            mega_pending = None
            if not any(self._chaos_due(r.job) for r in reqs):
                try:
                    with _span("serve.dispatch", jobs=len(reqs),
                               evals=total):
                        mega = np.concatenate([r.genomes for r in reqs])
                        mega_pending = self.engine.evaluate_genomes_async(
                            reqs[0].space, mega)
                except Exception as err:  # noqa: BLE001 - degrade to solo
                    _metrics.counter("serve.batch_fault").inc()
                    log.warning(f"[serve] mega-batch dispatch failed "
                                f"({type(err).__name__}: {err}); falling "
                                f"back to per-job dispatches")
                    mega_pending = None
            else:
                _metrics.counter("serve.batch_fault").inc()
            offset = 0
            for req in reqs:
                sl = slice(offset, offset + len(req.genomes))
                offset += len(req.genomes)
                req.fetch = self._make_fetch(req, mega_pending, sl)

    def _make_fetch(self, req: _EvalRequest, mega_pending, sl: slice):
        def fetch() -> GenomeEvalResult:
            if mega_pending is not None:
                try:
                    return _slice_result(mega_pending.result(), sl)
                except Exception as err:  # noqa: BLE001 - isolate batch-mates
                    _metrics.counter("serve.batch_fault").inc()
                    log.warning(f"[serve] mega-batch materialization "
                                f"failed ({type(err).__name__}: {err}); "
                                f"re-dispatching {req.job.job_id!r} solo")
            seen = {"attempts": 0}

            def attempt() -> GenomeEvalResult:
                if seen["attempts"]:
                    _metrics.counter("serve.retry").inc()
                seen["attempts"] += 1
                self._maybe_chaos(req.job)
                return self.engine.evaluate_genomes_async(
                    req.space, req.genomes).result()

            return call_with_retry(attempt, retries=self.retries,
                                   backoff=0.0,
                                   describe=f"serve-solo:{req.job.job_id}")

        return fetch

    # -- per-job completion path --------------------------------------------
    def _finish_job_step(self, job: Job, pending) -> None:
        try:
            ev = pending.result()
            with _span("serve.ingest", job=job.job_id):
                job.optimizer.finish_step(ev)
            job.gen_seconds.append(time.perf_counter() - job._gen_t0)
            _metrics.histogram("serve.generation_s").observe(
                job.gen_seconds[-1])
            if self._ckpt_due(job):
                self._checkpoint(job)
            if job.finished():
                self._complete(job)
        except Exception as err:  # noqa: BLE001 - isolate per job
            self._fail(job, "error", f"{type(err).__name__}: {err}")

    def _ckpt_due(self, job: Job) -> bool:
        return (self.state_dir is not None and self.ckpt_every > 0
                and job.optimizer.generation % self.ckpt_every == 0)

    def _ckpt_path(self, job: Job) -> str:
        return os.path.join(self.state_dir, f"job-{job.job_id}.json")

    def _front_path(self, job: Job) -> str:
        return os.path.join(self.state_dir, f"job-{job.job_id}.front.json")

    def _checkpoint(self, job: Job) -> None:
        save_checkpoint(self._ckpt_path(job), job.optimizer)

    def _complete(self, job: Job) -> None:
        job.result_rows = front_rows(job.optimizer, job.space)
        job.status = DONE
        if (job.spec.max_evals is not None
                and job.optimizer.generation < job.spec.generations):
            job.reason = "eval_budget"
        if self.state_dir:
            self._checkpoint(job)
            write_front(self._front_path(job), job.result_rows)
        self._terminal(job)
        log.info(f"[serve] job {job.job_id!r} done: "
                 f"{job.optimizer.generation} generations, "
                 f"{job.n_evals} evals, front {len(job.result_rows)}")

    def _fail(self, job: Job, reason: str, detail: str = "") -> None:
        job.status = FAILED
        job.reason = reason
        self._terminal(job)
        log.warning(f"[serve] job {job.job_id!r} failed ({reason}): "
                    f"{detail}")

    def _terminal(self, job: Job) -> None:
        if job.started_at is not None:
            job.wall_s = time.monotonic() - job.started_at
        _metrics.counter("serve.jobs", status=job.status).inc()
        with self._lock:
            if job in self._running:
                self._running.remove(job)
            self._write_manifest()
        job.done_event.set()

    # -- drain / restart ----------------------------------------------------
    def _suspend_all(self) -> None:
        """Under lock, at a round boundary: checkpoint every running job
        and park it (with everything queued) for a restarted server."""
        for job in list(self._running):
            if self.state_dir and job.optimizer is not None:
                self._checkpoint(job)
            job.status = SUSPENDED
        for job in self._queue:
            job.status = SUSPENDED
        self._running.clear()
        self._queue.clear()
        self._write_manifest()

    def _manifest_path(self) -> str:
        return os.path.join(self.state_dir, "jobs.json")

    def _write_manifest(self) -> None:
        if not self.state_dir:
            return
        import json
        entries = []
        for job in self._jobs.values():
            entries.append({"spec": job.spec.to_dict(),
                            "status": job.status,
                            "reason": job.reason,
                            "n_evals": job.n_evals})
        path = self._manifest_path()
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"format": 1, "jobs": entries}, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)

    def _load_state_dir(self) -> None:
        """Adopt a previous server's manifest: terminal jobs are kept as
        records, everything else re-queues and resumes from its newest
        loadable checkpoint (bit-identically — the format-2 resume
        semantics of ``opt.runner``)."""
        import json
        path = self._manifest_path()
        if not os.path.exists(path):
            return
        try:
            with open(path) as f:
                manifest = json.load(f)
        except (OSError, json.JSONDecodeError) as err:
            log.warning(f"[serve] unreadable manifest {path} "
                        f"({type(err).__name__}: {err}); starting empty")
            return
        for entry in manifest.get("jobs", ()):
            spec = JobSpec.from_dict(entry["spec"])
            job = Job(spec)
            if entry.get("status") in TERMINAL:
                job.status = entry["status"]
                job.reason = entry.get("reason")
                if entry["status"] == DONE:
                    front = os.path.join(self.state_dir,
                                         f"job-{spec.job_id}.front.json")
                    if os.path.exists(front):
                        with open(front) as f:
                            job.result_rows = json.load(f)
                job.done_event.set()
            else:
                state, source = load_checkpoint_resilient(
                    os.path.join(self.state_dir, f"job-{spec.job_id}.json"))
                if state is not None:
                    job.resume_state = state
                    log.info(f"[serve] resuming job {spec.job_id!r} from "
                             f"{os.path.basename(source)} (generation "
                             f"{state.get('generation')})")
                job.status = QUEUED
                self._queue.append(job)
            self._jobs[spec.job_id] = job


__all__ = ["SearchService", "CoBatchEngine", "AdmissionError"]
