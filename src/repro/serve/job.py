"""Job model for the search service (ISSUE 10).

A *job* is one complete optimizer run — algorithm, search-space spec,
population size, generation count, seed, budgets — submitted to the
``SearchService``. The spec is plain JSON-serializable data so the same
object travels through the in-process API, the ``--jobs`` file of
``python -m repro.serve``, the HTTP front-end, and the drain manifest.

The one rule that everything else in ``repro.serve`` leans on: a job's
entire trajectory is a deterministic function of its spec. All RNG draws
come from the job's own seeded stream inside the optimizer's
``begin_step``/``finish_step`` calls, and the device evaluation is
row-exact under co-batching (see ``service.py``), so ``run_spec_solo``
— the plain synchronous reference driver below — defines the ground
truth every served job must reproduce bit-for-bit.
"""
from __future__ import annotations

import json
import os
import threading
from dataclasses import dataclass, field

from ..opt.algorithms import Budgets, PopulationEvaluator
from ..opt.runner import make_optimizer, make_space

# Job lifecycle. QUEUED -> RUNNING -> DONE | FAILED; SUSPENDED is the
# drain state (checkpointed, waiting for a restarted server to resume).
QUEUED = "queued"
RUNNING = "running"
DONE = "done"
FAILED = "failed"
SUSPENDED = "suspended"
TERMINAL = (DONE, FAILED)

_DEFAULT_SPACE = {"kind": "adjacency", "n_chiplets": 10, "max_degree": 4}


@dataclass
class JobSpec:
    """One search job, as plain data (JSON round-trips exactly)."""
    job_id: str
    algo: str = "nsga2"                    # nsga2 | sa | random
    generations: int = 8
    pop_size: int = 8
    seed: int = 0
    tenant: str = "default"
    # make_space(**space): {"kind": "adjacency"|"parametric", ...params}
    space: dict = field(default_factory=lambda: dict(_DEFAULT_SPACE))
    budgets: dict = field(default_factory=dict)   # Budgets(**budgets)
    max_evals: int | None = None           # per-job eval budget
    deadline_s: float | None = None        # wall deadline from admission
    # Test/chaos hook: the job's dispatch raises BackendChaosError at this
    # generation — the fault-isolation path must fail THIS job only.
    chaos_fail_generation: int | None = None

    def to_dict(self) -> dict:
        return {"job_id": self.job_id, "algo": self.algo,
                "generations": self.generations, "pop_size": self.pop_size,
                "seed": self.seed, "tenant": self.tenant,
                "space": dict(self.space), "budgets": dict(self.budgets),
                "max_evals": self.max_evals, "deadline_s": self.deadline_s,
                "chaos_fail_generation": self.chaos_fail_generation}

    @classmethod
    def from_dict(cls, d: dict) -> "JobSpec":
        return cls(**{k: d[k] for k in cls.__dataclass_fields__ if k in d})

    def validate(self) -> None:
        from ..opt.algorithms import ALGORITHMS
        if self.algo not in ALGORITHMS:
            raise ValueError(f"unknown algo {self.algo!r}")
        if self.generations < 1 or self.pop_size < 1:
            raise ValueError("generations and pop_size must be >= 1")
        if self.space.get("kind") not in ("adjacency", "parametric"):
            raise ValueError(f"unknown space kind "
                             f"{self.space.get('kind')!r}")

    def space_key(self) -> tuple:
        """Canonical hashable identity of the search space: jobs with the
        same key share ONE space instance, one device pipeline, and one
        jit cache — the unit of cross-job co-batching."""
        return tuple(sorted((k, _canon(v)) for k, v in self.space.items()))


def _canon(value):
    """JSON round-trips tuples as lists; canonicalize for hashing and
    for the tuple-typed ParametricSpace fields."""
    return tuple(value) if isinstance(value, (list, tuple)) else value


def make_job_space(spec: JobSpec):
    kw = {k: _canon(v) for k, v in spec.space.items()}
    return make_space(kw.pop("kind"), **kw)


def make_job_optimizer(spec: JobSpec, space, evaluator: PopulationEvaluator):
    size_kw = {"random": "batch_size", "sa": "n_chains",
               "nsga2": "pop_size"}[spec.algo]
    return make_optimizer(spec.algo, space, evaluator, seed=spec.seed,
                          **{size_kw: spec.pop_size})


def eval_budget_reached(optimizer, spec: JobSpec) -> bool:
    """True when dispatching one more generation would overrun the job's
    eval budget — checked BEFORE ``begin_step`` so the RNG stream of a
    budget-stopped job is a prefix of the unbudgeted stream (shared by
    the service scheduler and ``run_spec_solo``)."""
    return (spec.max_evals is not None
            and optimizer.evaluator.n_evals + spec.pop_size > spec.max_evals)


class Job:
    """Mutable service-side record for one submitted spec."""

    def __init__(self, spec: JobSpec):
        self.spec = spec
        self.status = QUEUED
        self.reason: str | None = None     # why FAILED / stopped early
        self.space = None
        self.optimizer = None
        self.resume_state: dict | None = None   # checkpoint to load on start
        self.result_rows: list | None = None
        self.gen_seconds: list[float] = []
        self.wall_s: float | None = None
        self.started_at: float | None = None    # monotonic
        self.deadline_at: float | None = None   # monotonic
        self.done_event = threading.Event()

    @property
    def job_id(self) -> str:
        return self.spec.job_id

    @property
    def generation(self) -> int:
        return 0 if self.optimizer is None else self.optimizer.generation

    @property
    def n_evals(self) -> int:
        return (0 if self.optimizer is None
                else self.optimizer.evaluator.n_evals)

    def finished(self) -> bool:
        return (self.optimizer is not None
                and (self.optimizer.generation >= self.spec.generations
                     or eval_budget_reached(self.optimizer, self.spec)))

    def summary(self) -> dict:
        return {"job_id": self.job_id, "status": self.status,
                "reason": self.reason, "tenant": self.spec.tenant,
                "generation": self.generation,
                "generations": self.spec.generations,
                "n_evals": self.n_evals}


def run_spec_solo(spec: JobSpec, engine=None) -> tuple:
    """The ground-truth reference: run one spec synchronously to
    completion on a private evaluator and return ``(optimizer, rows)``.
    Every served job's front must be bit-identical to this (asserted in
    tests/test_serve.py and benchmarks/serve_load.py)."""
    space = make_job_space(spec)
    evaluator = PopulationEvaluator(space, engine=engine,
                                    budgets=Budgets(**spec.budgets))
    opt = make_job_optimizer(spec, space, evaluator)
    while (opt.generation < spec.generations
           and not eval_budget_reached(opt, spec)):
        opt.step()
    return opt, front_rows(opt, space)


def front_rows(optimizer, space) -> list[dict]:
    """The archive front as JSON-ready rows (the byte-comparison unit of
    the bit-identity guarantee)."""
    from ..opt.runner import OptResult
    res = OptResult(archive=optimizer.archive,
                    n_evals=optimizer.evaluator.n_evals,
                    generations=optimizer.generation)
    return res.to_rows(space)


def front_json_bytes(rows: list[dict]) -> bytes:
    """Canonical serialization of a front — every producer (service,
    CLI, solo reference, benchmark) uses THIS, so byte comparison means
    value comparison."""
    return (json.dumps(rows, sort_keys=True, separators=(",", ":"))
            + "\n").encode("utf-8")


def write_front(path: str, rows: list[dict]) -> None:
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(front_json_bytes(rows))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


__all__ = ["JobSpec", "Job", "QUEUED", "RUNNING", "DONE", "FAILED",
           "SUSPENDED", "TERMINAL", "make_job_space", "make_job_optimizer",
           "eval_budget_reached", "run_spec_solo", "front_rows",
           "front_json_bytes", "write_front"]
