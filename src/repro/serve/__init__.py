"""Design-search-as-a-service (ISSUE 10): a persistent, fault-isolated
multi-job search server that co-batches concurrent NSGA-II/SA/random
jobs into shared device dispatches. See ``serve.service.SearchService``
(in-process API) and ``python -m repro.serve`` (CLI/daemon)."""
from .job import (Job, JobSpec, front_json_bytes, front_rows,
                  run_spec_solo, write_front)
from .service import AdmissionError, CoBatchEngine, SearchService

__all__ = ["SearchService", "CoBatchEngine", "AdmissionError", "Job",
           "JobSpec", "run_spec_solo", "front_rows", "front_json_bytes",
           "write_front"]
