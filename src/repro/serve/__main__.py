"""``python -m repro.serve`` — the search service as a daemon.

Runs a ``SearchService`` over a persistent ``--state-dir``: jobs come
from a ``--jobs`` JSON file (a list of ``JobSpec`` dicts) and/or the
optional ``--http`` front-end; every completed job's front lands in the
state dir as ``job-<id>.front.json`` (canonical bytes — see
``serve.job.front_json_bytes``). SIGTERM/SIGINT triggers a graceful
drain: the in-flight round finishes, every running job is checkpointed
(format-2, checksummed), and a server restarted on the same state dir
resumes every job bit-identically. A SIGKILL is also survivable — jobs
checkpoint every generation by default (``REPRO_SERVE_CKPT_EVERY``).

HTTP front-end (stdlib only, enabled with ``--http PORT``)::

    POST /jobs   {JobSpec json}   -> {"job_id": ...} | 429 {"error": reason}
    GET  /jobs/<id>               -> job summary
    GET  /stats                   -> scheduler stats
    POST /drain                   -> begin graceful drain

Example::

    PYTHONPATH=src python -m repro.serve --state-dir serve_state \
        --jobs jobs.json --exit-when-idle
"""
from __future__ import annotations

import argparse
import json
import threading
import time

from ..faults.harness import graceful_shutdown
from ..obs.log import get_logger
from .job import TERMINAL, JobSpec
from .service import AdmissionError, SearchService

log = get_logger("repro.serve")


def _parse_tenant_budgets(items: list[str]) -> dict:
    budgets = {}
    for item in items:
        tenant, _, evals = item.partition("=")
        if not evals:
            raise ValueError(f"--tenant-budget wants TENANT=EVALS, "
                             f"got {item!r}")
        budgets[tenant] = int(evals)
    return budgets


def _http_server(service: SearchService, port: int,
                 drain_requested: threading.Event):
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    class Handler(BaseHTTPRequestHandler):
        def log_message(self, fmt, *args):      # route to obs, not stderr
            log.debug(f"[serve.http] {fmt % args}")

        def _reply(self, code: int, payload: dict) -> None:
            body = json.dumps(payload).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def do_GET(self):
            if self.path == "/stats":
                self._reply(200, service.stats())
                return
            if self.path.startswith("/jobs/"):
                job_id = self.path[len("/jobs/"):]
                try:
                    self._reply(200, service.job(job_id).summary())
                except KeyError:
                    self._reply(404, {"error": f"no job {job_id!r}"})
                return
            self._reply(404, {"error": f"unknown path {self.path!r}"})

        def do_POST(self):
            if self.path == "/drain":
                drain_requested.set()
                self._reply(200, {"draining": True})
                return
            if self.path == "/jobs":
                length = int(self.headers.get("Content-Length", 0))
                try:
                    spec = JobSpec.from_dict(
                        json.loads(self.rfile.read(length)))
                    self._reply(200, {"job_id": service.submit(spec)})
                except AdmissionError as err:
                    self._reply(429, {"error": err.reason,
                                      "detail": str(err)})
                except (TypeError, ValueError,
                        json.JSONDecodeError) as err:
                    self._reply(400, {"error": "bad_spec",
                                      "detail": str(err)})
                return
            self._reply(404, {"error": f"unknown path {self.path!r}"})

    server = ThreadingHTTPServer(("127.0.0.1", port), Handler)
    thread = threading.Thread(target=server.serve_forever,
                              name="repro-serve-http", daemon=True)
    thread.start()
    log.info(f"[serve] http front-end on 127.0.0.1:{server.server_port}")
    return server


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="Persistent multi-job search service with co-batched "
                    "device dispatches, fault isolation, and graceful "
                    "drain/resume.")
    p.add_argument("--state-dir", required=True,
                   help="checkpoint/manifest/front directory; a restarted "
                        "server on the same dir resumes every job")
    p.add_argument("--jobs", type=str, default=None,
                   help="JSON file with a list of JobSpec dicts to submit")
    p.add_argument("--http", type=int, default=None, metavar="PORT",
                   help="serve the HTTP front-end on 127.0.0.1:PORT")
    p.add_argument("--max-jobs", type=int, default=None,
                   help="concurrently running job cap "
                        "(default REPRO_SERVE_MAX_JOBS)")
    p.add_argument("--max-queued", type=int, default=None,
                   help="queued job cap before shedding "
                        "(default REPRO_SERVE_MAX_QUEUED)")
    p.add_argument("--tenant-budget", action="append", default=[],
                   metavar="TENANT=EVALS",
                   help="per-tenant eval budget (repeatable)")
    p.add_argument("--exit-when-idle", action="store_true",
                   help="exit once every submitted job is terminal")
    args = p.parse_args(argv)

    service = SearchService(
        state_dir=args.state_dir, max_jobs=args.max_jobs,
        max_queued=args.max_queued,
        tenant_budgets=_parse_tenant_budgets(args.tenant_budget))
    if args.jobs:
        with open(args.jobs) as f:
            specs = json.load(f)
        for spec in specs:
            try:
                service.submit(JobSpec.from_dict(spec))
            except AdmissionError as err:
                log.warning(f"[serve] jobs file entry rejected: {err}")
    service.start()

    drain_requested = threading.Event()
    server = (_http_server(service, args.http, drain_requested)
              if args.http is not None else None)

    with graceful_shutdown() as stop:
        while True:
            if stop.requested() or drain_requested.is_set():
                log.warning("[serve] drain requested; checkpointing "
                            "in-flight jobs")
                break
            stats = service.stats()
            idle = (stats["queue_depth"] == 0 and stats["running"] == 0
                    and all(j.status in TERMINAL for j in service.jobs()))
            if args.exit_when_idle and idle:
                log.info("[serve] idle and --exit-when-idle set; draining")
                break
            time.sleep(0.05)
    service.drain()
    if server is not None:
        server.shutdown()
    stats = service.stats()
    log.info(f"[serve] exit: {stats['jobs']} after {stats['rounds']} "
             f"rounds, {stats['evals_total']} evals")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
