"""Architecture registry: ``--arch <id>`` lookup for every assigned
architecture (exact published dimensions; see each module's citation)."""
from __future__ import annotations

import importlib

_ARCH_MODULES = {
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "nemotron-4-15b": "nemotron_4_15b",
    "qwen2.5-3b": "qwen2_5_3b",
    "glm4-9b": "glm4_9b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "llava-next-34b": "llava_next_34b",
    "whisper-medium": "whisper_medium",
    "hymba-1.5b": "hymba_1_5b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch: str):
    try:
        mod_name = _ARCH_MODULES[arch]
    except KeyError:
        raise ValueError(
            f"unknown arch {arch!r}; options: {', '.join(ARCH_IDS)}") from None
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
