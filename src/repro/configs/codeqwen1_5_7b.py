"""codeqwen1.5-7b [dense]: 32L d_model=4096 32H (MHA kv=32) d_ff=13440
vocab=92416 — qwen1.5 architecture (QKV bias).
[hf:Qwen/CodeQwen1.5-7B; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="decoder",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab_size=92416,
    attention="gqa",
    qkv_bias=True,
    mlp="swiglu",
    rope_theta=1000000.0,
)
