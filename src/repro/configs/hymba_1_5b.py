"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention + mamba heads.
[arXiv:2411.13676; hf]

Each block computes sliding-window attention and a Mamba mixer on the same
normed input and sums them (the paper's parallel-head hybrid). Deviations
recorded in DESIGN.md: all attention layers use SWA (the released model
keeps 3 full-attention layers) and meta tokens are omitted. The SWA ring
cache + O(1) SSM state make long_500k runnable.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_head=64,
    d_ff=5504,
    vocab_size=32001,
    attention="gqa",
    window=2048,
    mlp="swiglu",
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    ssm_dt_rank=100,
    rope_theta=10000.0,
)
