"""qwen2.5-3b [dense]: 36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936 — GQA with QKV bias. [hf:Qwen/Qwen2.5-*; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="decoder",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    d_ff=11008,
    vocab_size=151936,
    attention="gqa",
    qkv_bias=True,
    mlp="swiglu",
    rope_theta=1000000.0,
)
