"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attention-free) vocab=65024,
ssm_state=16 — Mamba-1 architecture. [arXiv:2410.05355; unverified]

Attention-free: O(1) decode state, so this arch runs the long_500k shape
(DESIGN.md §Arch-applicability).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,               # unused (attention-free)
    n_kv_heads=1,
    d_head=64,
    d_ff=0,
    vocab_size=65024,
    attention="none",
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    ssm_dt_rank=256,
    ssm_chunk=256,
)
