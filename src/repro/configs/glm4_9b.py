"""glm4-9b [dense]: 40L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=151552 — partial RoPE, GQA. [hf:THUDM/glm-4-9b; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="decoder",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    attention="gqa",
    qkv_bias=True,           # GLM-4 uses attention bias
    mlp="swiglu",
    rotary_pct=0.5,          # GLM partial rotary
    rope_theta=10000.0,
)
