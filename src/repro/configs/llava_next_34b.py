"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling frontend (STUB: input_specs provides
precomputed patch embeddings). [hf:llava-hf/llava-v1.6-*; unverified]

The backbone is the Yi-34B-class decoder; the vision tower + anyres tiling
is a modality frontend stub per the assignment: 576 patch embeddings are
prepended to the text sequence (within the assigned seq_len budget).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    attention="gqa",
    mlp="swiglu",
    rope_theta=5000000.0,
    n_image_tokens=576,
)
