"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE 64 routed experts top-6 + 2 shared — MLA kv_lora=512.
[arXiv:2405.04434; hf]

Note: the assignment inline text says "160 routed" but the headline config
("MoE 64e top-6") and the HF DeepSeek-V2-Lite checkpoint both say 64 routed
experts; we follow 64 (recorded in DESIGN.md §Arch-applicability).
DeepSeek-V2-Lite has no q-LoRA (q_lora_rank=0) and its first layer is dense.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="decoder",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=10944,              # dense-layer FFN (first layer)
    vocab_size=102400,
    attention="mla",
    kv_lora_rank=512,
    q_lora_rank=0,
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
    mlp="swiglu",
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    rope_theta=10000.0,
)
