"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (GQA kv=16) d_ff=1024
vocab=50304, MoE 64 experts top-8. [arXiv:2409.02060; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="decoder",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,               # unused (all layers MoE); kept for the record
    vocab_size=50304,
    attention="gqa",
    mlp="swiglu",
    n_experts=64,
    n_shared_experts=0,
    top_k=8,
    moe_d_ff=1024,
    rope_theta=10000.0,
)
