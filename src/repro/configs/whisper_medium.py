"""whisper-medium [audio]: 24L d_model=1024 16H d_ff=4096 vocab=51865 —
encoder-decoder; conv frontend STUB (input_specs provides precomputed frame
embeddings, 1500 frames). [arXiv:2212.04356; unverified]

Whisper-medium is 24 encoder + 24 decoder layers, LayerNorm + GELU, learned
positions, full (not rotary) attention. The decoder serves the decode
shapes (self-attn KV cache + fixed cross-attention KV).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,             # decoder layers
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    attention="gqa",
    mlp="gelu",
    rotary_pct=0.0,          # learned absolute positions, no RoPE
    n_audio_frames=1500,
)
