"""Compatibility shims across supported jax versions (0.4.37+).

* ``shard_map``: exported from ``jax`` at top level since 0.5; lives in
  ``jax.experimental.shard_map`` on 0.4.x.
* ``make_auto_mesh``: ``jax.make_mesh(..., axis_types=(AxisType.Auto, ...))``
  on jax versions that have ``AxisType``; a plain ``jax.make_mesh`` (same
  sharding behavior) on 0.4.x, which predates explicit axis types.
"""
from __future__ import annotations

import jax

try:                                    # jax >= 0.5
    from jax import shard_map as _shard_map
    _UNCHECKED_KW = "check_vma"
except ImportError:                     # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _UNCHECKED_KW = "check_rep"


def shard_map(f=None, **kwargs):
    """jax.shard_map with the replication-check kwarg renamed per version
    (``check_vma`` on jax >= 0.5, ``check_rep`` on 0.4.x)."""
    for alias in ("check_vma", "check_rep"):
        if alias in kwargs and alias != _UNCHECKED_KW:
            kwargs[_UNCHECKED_KW] = kwargs.pop(alias)
    if f is None:
        return lambda g: _shard_map(g, **kwargs)
    return _shard_map(f, **kwargs)


def make_auto_mesh(shape: tuple[int, ...], axes: tuple[str, ...],
                   devices=None) -> jax.sharding.Mesh:
    kwargs = {} if devices is None else {"devices": devices}
    try:
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
                             **kwargs)
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes, **kwargs)
