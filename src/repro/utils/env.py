"""Central registry of every ``REPRO_*`` environment knob (ISSUE 8).

Every env-var read in the package goes through this module: the knob's
name, type, default, and doc live in ONE place, ``python -m repro.analysis
--env`` prints the table, and the repo lint (``repro.analysis.lint``, rule
``env-read``) rejects stray ``os.environ["REPRO_*"]`` reads anywhere else
in ``src/``. Reads stay *dynamic* — the value is fetched from the process
environment at every call, exactly like the scattered ``os.environ.get``
calls this replaces — so flipping a knob mid-process behaves as before
(subject to each call site's own trace-time caveats).

Semantics preserved from the original call sites:

* ``get_int`` — ``int(os.environ.get(name, default))``;
* ``get_opt_int`` — ``int(v) if v else None`` (unset and ``""`` both mean
  "auto");
* ``get_str`` — the raw string, knob default when unset;
* ``get_bool`` — false for ``"" / "0" / "false" / "off"`` (the
  ``REPRO_TRACE`` truthiness rule).

``override(NAME=value, OTHER=None)`` is a context manager for tests and
the jaxpr auditor: it sets (or, for ``None``, unsets) variables and
restores the previous state on exit.
"""
from __future__ import annotations

import os
from contextlib import contextmanager
from dataclasses import dataclass

_FALSY = ("", "0", "false", "off")


@dataclass(frozen=True)
class Knob:
    """One registered environment knob."""
    name: str
    type: str                 # "int" | "str" | "bool" | "path"
    default: object           # None = unset / auto
    doc: str
    choices: tuple | None = None


KNOBS: dict[str, Knob] = {}


def _register(name: str, type: str, default, doc: str,
              choices: tuple | None = None) -> Knob:
    knob = Knob(name, type, default, doc, choices)
    KNOBS[name] = knob
    return knob


# --- kernels ---------------------------------------------------------------
_register(
    "REPRO_PALLAS_INTERPRET", "str", "1",
    "'1' (default) runs Pallas kernels through the interpreter (the CPU "
    "container); '0' compiles them for hardware and makes compiled Pallas "
    "the default kernel backend everywhere.")
_register(
    "REPRO_LOAD_PROP_BACKEND", "str", None,
    "Force the load-propagation backend (auto-selected per runtime when "
    "unset).",
    choices=("pallas", "pallas_interpret", "xla", "pallas_tiled",
             "pallas_tiled_interpret", "xla_blocked"))
_register(
    "REPRO_LOAD_PROP_FUSED_N", "int", 160,
    "Node count above which load propagation promotes the fused/dense "
    "backends to their destination-tiled twins.")
_register(
    "REPRO_LOAD_PROP_TILE", "int", None,
    "Pin the destination-tile size of the tiled load-propagation variants "
    "(auto via load_prop.pick_tile when unset).")
_register(
    "REPRO_APSP_BACKEND", "str", None,
    "Force the APSP backend (auto-selected per runtime when unset).",
    choices=("pallas", "pallas_interpret", "xla", "pallas_tiled",
             "pallas_tiled_interpret", "xla_blocked"))
_register(
    "REPRO_APSP_FUSED_N", "int", 160,
    "Node count above which APSP promotes the fused/dense backends to "
    "their blocked twins.")
_register(
    "REPRO_APSP_TILE", "int", None,
    "Pin the row-slab tile size of the blocked APSP variants (auto when "
    "unset).")

# --- routing ---------------------------------------------------------------
_register(
    "REPRO_ROUTING_BLOCK_N", "int", 160,
    "Node count above which routing-table construction switches to the "
    "destination-blocked scans (read at trace time).")
_register(
    "REPRO_ROUTING_TILE", "int", None,
    "Pin the destination-slab tile of the blocked routing scans (auto via "
    "load_prop.pick_tile when unset).")

# --- faults / graceful degradation -----------------------------------------
_register(
    "REPRO_STRICT_BACKEND", "bool", "0",
    "Disable the kernel-backend fallback ladder: a dispatch failure "
    "raises instead of retrying on the next rung (faults/harness.py).")
_register(
    "REPRO_CHAOS_BACKEND_FAIL", "str", None,
    "Comma-separated kernel backend names that fail on purpose at "
    "dispatch (chaos testing of the fallback ladder; never set in "
    "production).")
_register(
    "REPRO_SIM_WATCHDOG_S", "int", 0,
    "SIGALRM deadline in seconds around each FastSim saturation probe "
    "(0 = no watchdog). A probe that exceeds it is retried with backoff "
    "(faults/harness.call_with_retry).")
_register(
    "REPRO_SIM_RETRIES", "int", 1,
    "Bounded retry count for saturation probes that time out or raise "
    "(0 = fail fast).")

# --- sim -------------------------------------------------------------------
_register(
    "REPRO_CKERNEL_DIR", "path", None,
    "Cache directory for the runtime-compiled FastSim C kernel "
    "(default: $XDG_CACHE_HOME/repro_simfast_ckernel, mode 0700).")

# --- observability ---------------------------------------------------------
_register(
    "REPRO_TRACE", "bool", "0",
    "Enable the process-wide span tracer at import "
    "('', '0', 'false', 'off' = disabled).")
_register(
    "REPRO_LOG", "str", "info",
    "Process-wide log verbosity of the 'repro' logging root.",
    choices=("debug", "info", "quiet", "warning", "error"))

# --- benchmarks ------------------------------------------------------------
_register(
    "REPRO_BENCH_FULL", "bool", "0",
    "Run benchmarks at full scale instead of the smoke subset.")
_register(
    "REPRO_OPT_BENCH_POP", "int", 16,
    "Population size of the optimizer convergence benchmark.")
_register(
    "REPRO_OPT_BENCH_GENS", "int", 10,
    "Generation count of the optimizer convergence benchmark.")
_register(
    "REPRO_OPT_BENCH_N", "int", 32,
    "Chiplet count of the optimizer convergence benchmark's free-form "
    "space.")
_register(
    "REPRO_BENCH_LARGE_N_NS", "str", "64,144,256,576",
    "Comma-separated (square) node counts for the large-n kernel and "
    "optimizer scaling tables.")
_register(
    "REPRO_SWEEP_PREP_POINTS", "int", 1000,
    "Design-point count of the sweep-preparation benchmark.")

# --- search service (repro.serve) ------------------------------------------
_register(
    "REPRO_SERVE_MAX_JOBS", "int", 8,
    "Search service: jobs running (co-batched) concurrently; further "
    "admitted jobs queue.")
_register(
    "REPRO_SERVE_MAX_QUEUED", "int", 64,
    "Search service: queued-job bound; submissions beyond it are shed "
    "with reason 'queue_full'.")
_register(
    "REPRO_SERVE_RETRIES", "int", 1,
    "Search service: bounded per-job solo-dispatch retries after a "
    "mega-batch or solo evaluation failure, before the job is FAILED.")
_register(
    "REPRO_SERVE_DEADLINE_S", "int", 0,
    "Search service: default per-job wall deadline in seconds (0 = "
    "none); JobSpec.deadline_s overrides per job.")
_register(
    "REPRO_SERVE_CKPT_EVERY", "int", 1,
    "Search service: checkpoint every running job each N generations "
    "(0 disables periodic snapshots; drain still checkpoints).")
_register(
    "REPRO_SERVE_DRAIN_TIMEOUT_S", "int", 30,
    "Search service: seconds drain() waits for the scheduler to finish "
    "the in-flight round and checkpoint before giving up.")


def _knob(name: str) -> Knob:
    try:
        return KNOBS[name]
    except KeyError:
        raise KeyError(
            f"{name} is not a registered REPRO_* knob; add it to "
            f"repro/utils/env.py (see `python -m repro.analysis --env`)"
        ) from None


def get_str(name: str) -> str | None:
    """Raw string value; the knob default when unset."""
    knob = _knob(name)
    v = os.environ.get(name)
    return knob.default if v is None else v


def get_int(name: str) -> int:
    """``int(value)``; the knob default when unset."""
    knob = _knob(name)
    v = os.environ.get(name)
    return int(knob.default) if v is None else int(v)


def get_opt_int(name: str) -> int | None:
    """``int(value)``, or None when unset/empty (= "auto")."""
    _knob(name)
    v = os.environ.get(name)
    return int(v) if v else None


def get_bool(name: str) -> bool:
    """Truthy unless unset-default/'', '0', 'false', or 'off'."""
    knob = _knob(name)
    v = os.environ.get(name)
    if v is None:
        v = knob.default if knob.default is not None else ""
    return str(v).lower() not in _FALSY


@contextmanager
def override(**values):
    """Temporarily set (value) or unset (None) environment knobs; restores
    the prior environment on exit. Keys must be registered knobs — typos
    fail loudly instead of silently not overriding anything."""
    for name in values:
        _knob(name)
    saved = {name: os.environ.get(name) for name in values}
    try:
        for name, v in values.items():
            if v is None:
                os.environ.pop(name, None)
            else:
                os.environ[name] = str(v)
        yield
    finally:
        for name, old in saved.items():
            if old is None:
                os.environ.pop(name, None)
            else:
                os.environ[name] = old


def table() -> list[dict]:
    """One row per knob (name, type, default, current, doc) — the
    ``python -m repro.analysis --env`` listing."""
    rows = []
    for name in sorted(KNOBS):
        k = KNOBS[name]
        cur = os.environ.get(name)
        rows.append({
            "name": k.name, "type": k.type,
            "default": "(auto)" if k.default is None else str(k.default),
            "current": "(unset)" if cur is None else cur,
            "doc": k.doc,
            "choices": "|".join(k.choices) if k.choices else "",
        })
    return rows


def format_table() -> str:
    rows = table()
    cols = ("name", "type", "default", "current")
    widths = {c: max(len(c), *(len(r[c]) for r in rows)) for c in cols}
    lines = ["  ".join(c.ljust(widths[c]) for c in cols)]
    lines.append("  ".join("-" * widths[c] for c in cols))
    for r in rows:
        lines.append("  ".join(r[c].ljust(widths[c]) for c in cols))
        lines.append(" " * 4 + r["doc"]
                     + (f" [{r['choices']}]" if r["choices"] else ""))
    return "\n".join(lines)


__all__ = ["Knob", "KNOBS", "get_str", "get_int", "get_opt_int", "get_bool",
           "override", "table", "format_table"]
