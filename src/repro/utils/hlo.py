"""HLO text analysis: collective-traffic accounting for the roofline.

``cost_analysis()`` does not report collective bytes, so we parse the
compiled HLO module text and sum the *result* shapes of every collective op
(per-device bytes, since the SPMD module is per-partition):

    %all-reduce.5 = bf16[8,1024]{1,0} all-reduce(...)
    %ag = (f32[4,128]{1,0}, f32[4,128]{1,0}) all-gather(...)

For all-reduce the result equals the payload; for all-gather the result is
the post-gather shape (an upper bound on received bytes, (k-1)/k of which
crosses links); reduce-scatter's result is the post-scatter shard (we count
the operand instead, matching what the links carry). The roofline divides by
per-link bandwidth, consistent with the assignment's formula.
"""
from __future__ import annotations

import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# instruction line: %name = <shape-or-tuple> <op>(...)
_INSTR_RE = re.compile(
    r"=\s*(\(.*?\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def parse_shape_bytes(text: str) -> int:
    """Total bytes of every dtype[dims] shape literal in ``text``."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind result bytes (per device). '-start' ops are
    counted; their '-done' halves are skipped (async pairs)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for m in _INSTR_RE.finditer(hlo_text):
        shape_txt, kind = m.group(1), m.group(2)
        # avoid double counting async -done ops: the regex matches both
        # "-start(" and "-done(" suffixes; detect "-done" by look-back.
        tail = hlo_text[m.end(2):m.end(2) + 6]
        if tail.startswith("-done"):
            continue
        out[kind] += parse_shape_bytes(shape_txt)
        counts[kind] += 1
    out["ops"] = counts
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out
