"""Version identification for checkpoints and telemetry (ISSUE 7).

``repro`` is a namespace package, so the version lives in the installed
distribution metadata; source-tree runs (PYTHONPATH=src without an
install) fall back to the pyproject default.
"""
from __future__ import annotations

_DIST_NAME = "repro-rapidchiplet"
_FALLBACK = "0.1.0"


def repro_version() -> str:
    try:
        from importlib.metadata import version
        return version(_DIST_NAME)
    except Exception:
        return _FALLBACK


def version_stamp(config_hash: str | None = None) -> dict:
    """The {repro, jax[, config_hash]} triple embedded in checkpoint
    snapshots so a resume from a different code/config version warns
    instead of silently mixing trajectories."""
    import jax
    stamp = {"repro": repro_version(), "jax": jax.__version__}
    if config_hash is not None:
        stamp["config_hash"] = str(config_hash)
    return stamp


def check_version_stamp(stamp: dict | None, config_hash: str | None = None,
                        what: str = "checkpoint") -> list[str]:
    """Mismatch descriptions between a stored stamp and the current
    process (empty == clean). ``None``/missing stamps (pre-ISSUE-7
    snapshots) report themselves so callers can warn once."""
    if not stamp:
        return [f"{what} predates version stamping (no versions recorded)"]
    current = version_stamp(config_hash)
    out = []
    for key, now in current.items():
        then = stamp.get(key)
        if then is not None and then != now:
            out.append(f"{what} was written with {key}={then}, "
                       f"this process runs {key}={now}")
    return out
