"""While-loop-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts each computation ONCE — a
``lax.scan`` over 36 layers contributes a single layer's FLOPs (verified
experimentally; see EXPERIMENTS.md §Dry-run methodology). Since the whole
framework leans on scan-over-layers for fast 512-device compiles, we parse
the compiled HLO text, build the computation call graph, extract while-loop
trip counts, and multiply:

    total = sum_over_computations( executions(comp) * cost(comp) )

Cost model per computation:
  flops   — 2 * prod(result_dims) * prod(contracting_dims) per dot op
            (cheap elementwise flops are ignored: dots dominate by >100x)
  bytes   — for every materializing instruction in non-fused computations:
            result bytes + operand bytes (fusion instructions count once;
            their internals are register-level)
  collective bytes — result-shape bytes of all-reduce / all-gather /
            reduce-scatter / all-to-all / collective-permute ops

Execution counts:
  ENTRY x1; fusion/call/to_apply propagate the caller's count; while bodies
  multiply by the trip count (the s32 constant compared against in the
  condition computation — exact for lax.scan/fori_loop lowerings).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# Byte-traffic models for the roofline memory term (the truth on a real TPU
# lies between; both are reported — see EXPERIMENTS.md §Dry-run methodology):
#
# * optimistic ("fused"): only genuine materialization points count — dot /
#   conv operands+results, copies, cache updates, data movement, and
#   collectives. Assumes elementwise chains (masks, softmax pieces, norms)
#   fuse into their producers/consumers, as aggressive TPU fusion or a
#   Pallas kernel would.
# * pessimistic ("unfused"): additionally counts every fusion instruction's
#   operands+results. XLA:CPU wraps single elementwise ops into kLoop
#   fusions, so this approaches "every op touches HBM".
_COUNT_BYTES_OPS = {
    "dot", "convolution", "copy", "transpose", "reshape",
    "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
    "reduce", "reduce-window", "select-and-scatter", "concatenate",
    "slice", "pad", "sort", "rng", "rng-bit-generator", "custom-call",
    "cholesky", "triangular-solve", "fft",
} | set(_COLLECTIVES)
_PESSIMISTIC_EXTRA = {"fusion"}

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->", re.M)
# Shape group is permissive: large tuple shapes embed /*index=N*/ comments.
# The op is the first lowercase word followed by '(' after the '='.
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*?)\s*([a-z][a-z0-9\-]*)\(", re.M)


@dataclass
class Instruction:
    name: str
    shape: str          # raw shape text (maybe tuple)
    op: str
    line: str


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    by_name: dict = field(default_factory=dict)
    fused: bool = False       # referenced via calls=/to_apply=


def _operand_names(comp: Computation, inst: Instruction) -> list[str]:
    """Operand instruction names of an op call, tolerant of both HLO operand
    styles: bare ``op(%a, %b)`` and the inline-shape form newer XLA emits,
    ``op(f32[128,128]{1,0} %a, f32[...] %b)``. Only names that resolve within
    the computation are returned (shape dtypes like ``f32`` never do)."""
    args = inst.line.split("(", 1)[-1]
    named = [o for o in re.findall(r"%([\w\.\-]+)", args) if o in comp.by_name]
    if named:
        return named
    return [o for o in re.findall(r"[\w\.\-]+", args) if o in comp.by_name]


def _shape_bytes(shape_txt: str) -> int:
    total = 0
    for dtype, dims in re.findall(r"\b([a-z0-9]+)\[([\d,]*)\]", shape_txt):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(shape_txt: str) -> list[int]:
    m = re.search(r"\[([\d,]*)\]", shape_txt)
    if not m or not m.group(1):
        return []
    return [int(d) for d in m.group(1).split(",")]


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in hlo.splitlines():
        hdr = _COMP_HDR.match(line.strip()) if ("->" in line and "{" in line
                                                ) else None
        if hdr and not line.lstrip().startswith("%param"):
            cur = Computation(name=hdr.group(2))
            comps[cur.name] = cur
            # parameters carry shapes in the header signature
            for pname, pshape in re.findall(
                    r"([\w\.\-]+):\s*((?:\([^()]*\))|[a-z0-9]+\[[\d,]*\])",
                    hdr.group(3)):
                inst = Instruction(pname, pshape, "parameter", line)
                cur.instrs.append(inst)
                cur.by_name[pname] = inst
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if m:
            inst = Instruction(m.group(1), m.group(2), m.group(3), line)
            cur.instrs.append(inst)
            cur.by_name[inst.name] = inst
        elif line.strip() == "}":
            cur = None
    return comps


def _call_edges(comp: Computation):
    """Yield (callee_name, multiplier_kind) for calls from this comp."""
    for inst in comp.instrs:
        for kind, pat in (("calls", r"calls=%?([\w\.\-]+)"),
                          ("to_apply", r"to_apply=%?([\w\.\-]+)")):
            for callee in re.findall(pat, inst.line):
                yield callee, "fused", inst
        m = re.search(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)",
                      inst.line)
        if m:
            yield m.group(1), "while_cond", inst
            yield m.group(2), "while_body", inst
        for callee in re.findall(r"(?:true_computation|false_computation|"
                                 r"branch_computations)=\{?%?([\w\.\-]+)",
                                 inst.line):
            yield callee, "fused", inst


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the while condition (= the scan bound)."""
    best = 1
    for inst in cond.instrs:
        if inst.op == "constant":
            m = re.search(r"constant\((\d+)\)", inst.line)
            if m:
                best = max(best, int(m.group(1)))
        # fusion-wrapped compares keep the constant in the operand list
        for v in re.findall(r"constant\((\d+)\)", inst.line):
            best = max(best, int(v))
    return best


def _while_trips(inst: Instruction, comps: dict) -> int:
    """Trip count of a while instruction. Scheduled modules annotate it
    directly (``backend_config={"known_trip_count":{"n":"9"}}``); fall back to
    the largest constant in the condition computation."""
    m = re.search(r'known_trip_count[^}]*"n"\s*:\s*"?(\d+)', inst.line)
    if m:
        return int(m.group(1))
    mc = re.search(r"condition=%?([\w\.\-]+)", inst.line)
    if mc and mc.group(1) in comps:
        return _trip_count(comps[mc.group(1)])
    return 1


def _dot_flops(comp: Computation, inst: Instruction) -> float:
    result_elems = 1
    for d in _shape_dims(inst.shape):
        result_elems *= d
    # contracting dims come from the lhs operand's shape
    ops = _operand_names(comp, inst)
    cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.line)
    contract = 1
    if ops and cdims:
        lhs_dims = _shape_dims(comp.by_name[ops[0]].shape)
        for ci in cdims.group(1).split(","):
            if ci and int(ci) < len(lhs_dims):
                contract *= lhs_dims[int(ci)]
    return 2.0 * result_elems * contract


def _conv_flops(comp: Computation, inst: Instruction) -> float:
    """convolution flops ~= 2 * result_elems * (kernel spatial * in_ch)."""
    result_elems = 1
    for d in _shape_dims(inst.shape):
        result_elems *= d
    ops = _operand_names(comp, inst)
    kernel = 1
    if len(ops) >= 2:
        kd = _shape_dims(comp.by_name[ops[1]].shape)
        for d in kd[:-1]:       # all but output-feature dim (approximation)
            kernel *= d
    return 2.0 * result_elems * kernel


def _operand_bytes(comp: Computation, inst: Instruction) -> int:
    return sum(_shape_bytes(comp.by_name[o].shape)
               for o in _operand_names(comp, inst))


@dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0          # optimistic / fused model
    bytes_accessed_unfused: float = 0.0  # pessimistic model
    collective_bytes: float = 0.0
    collective_breakdown: dict = field(default_factory=dict)
    collective_ops: dict = field(default_factory=dict)
    while_loops: list = field(default_factory=list)


def analyze(hlo: str, exclude_bytes_substring: str | None = None) -> HloCost:
    """``exclude_bytes_substring``: skip byte accounting for instructions
    whose metadata op_name contains the substring. Used for interpret-mode
    Pallas kernels: their emulated internals lower to ordinary HLO that
    would read as HBM traffic, but on TPU they are VMEM-resident — the
    caller adds the kernel's true I/O analytically (launch/dryrun.py,
    variant ssm_fused)."""
    comps = parse_computations(hlo)

    # mark fused computations (register-level: no byte accounting)
    fused_names = set()
    for comp in comps.values():
        for callee, kind, _ in _call_edges(comp):
            if kind == "fused" and callee in comps:
                fused_names.add(callee)
    for name in fused_names:
        comps[name].fused = True

    # execution counts: propagate from ENTRY (the last computation in the
    # module text is ENTRY for scheduled modules; find via "ENTRY" keyword)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
    if entry is None or entry not in comps:
        # fall back: computation that nobody calls
        called = {c for comp in comps.values()
                  for c, _, _ in _call_edges(comp)}
        roots = [c for c in comps if c not in called]
        entry = roots[-1] if roots else next(iter(comps))

    exec_count: dict[str, float] = {name: 0.0 for name in comps}
    exec_count[entry] = 1.0
    # topological-ish propagation: iterate until fixpoint (call graph is a
    # DAG; bounded passes)
    for _ in range(len(comps)):
        changed = False
        new = {name: 0.0 for name in comps}
        new[entry] = 1.0
        for name, comp in comps.items():
            cnt = exec_count[name]
            if cnt <= 0:
                continue
            for callee, kind, inst in _call_edges(comp):
                if callee not in comps:
                    continue
                if kind == "while_body":
                    new[callee] += cnt * _while_trips(inst, comps)
                elif kind == "while_cond":
                    new[callee] += cnt * (_while_trips(inst, comps) + 1)
                else:
                    new[callee] += cnt
        new[entry] = 1.0
        if any(abs(new[k] - exec_count[k]) > 1e-9 for k in comps):
            changed = True
        exec_count = new
        if not changed:
            break

    out = HloCost(collective_breakdown={k: 0.0 for k in _COLLECTIVES},
                  collective_ops={k: 0 for k in _COLLECTIVES})
    for name, comp in comps.items():
        cnt = exec_count.get(name, 0.0)
        if cnt <= 0:
            continue
        for inst in comp.instrs:
            base_op = inst.op
            if base_op.endswith("-start"):
                base_op = base_op[:-6]
            if base_op == "dot":
                out.flops += cnt * _dot_flops(comp, inst)
            elif base_op == "convolution":
                out.flops += cnt * _conv_flops(comp, inst)
            if base_op in _COLLECTIVES and not inst.op.endswith("-done"):
                b = _shape_bytes(inst.shape)
                out.collective_bytes += cnt * b
                out.collective_breakdown[base_op] += cnt * b
                out.collective_ops[base_op] += int(cnt)
            if not comp.fused and not inst.op.endswith("-done"):
                counted = base_op in _COUNT_BYTES_OPS
                if (exclude_bytes_substring is not None
                        and exclude_bytes_substring in inst.line):
                    counted = False
                pess = counted or base_op in _PESSIMISTIC_EXTRA
                if counted or pess:
                    res_b = _shape_bytes(inst.shape)
                    if base_op in ("dynamic-slice", "slice", "gather"):
                        # reads only the slice, not the whole operand
                        b = cnt * 2 * res_b
                    elif base_op == "dynamic-update-slice":
                        # writes (and reads) only the update window
                        ops_b = [_shape_bytes(comp.by_name[o].shape)
                                 for o in _operand_names(comp, inst)]
                        b = cnt * 2 * (min(ops_b) if ops_b else res_b)
                    else:
                        b = cnt * (res_b + _operand_bytes(comp, inst))
                    if counted:
                        out.bytes_accessed += b
                    if pess:
                        out.bytes_accessed_unfused += b
        # record loop info for diagnostics
        for callee, kind, inst in _call_edges(comp):
            if kind == "while_body" and callee in comps:
                out.while_loops.append(
                    {"body": callee,
                     "trips": _while_trips(inst, comps),
                     "caller_count": cnt})
    return out


def xla_cost_analysis(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions: some
    return one dict, others a one-element list of per-partition dicts."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        return dict(ca[0]) if ca else {}
    return dict(ca)
