from .hlo import collective_bytes, parse_shape_bytes

__all__ = ["collective_bytes", "parse_shape_bytes"]
