"""Process-wide cache of per-structure sweep artifacts.

Sweep points that share (topology, n, routing, seed, packaging, technology)
and differ only in the traffic pattern need the *same* graph, routing table,
step costs, and routed diameter. Building those is the expensive host-side
part of sweep preparation (graph construction + routing-table relaxation), so
we build each unique structure once and reuse it:

* ``dse.batch.encode_designs`` groups design points by
  ``DesignPoint.structure_key()`` and hits this cache per group;
* ``core.ici_model.estimate_collective`` keys the 256-chip pod design here
  instead of rebuilding it on every collective estimate.

Entries are immutable by convention: consumers must treat the stored arrays
as read-only (they are shared across threads — the DSE engine encodes the
next chunk on a worker thread while the device evaluates the current one).
The cache is a bounded LRU guarded by a lock, so concurrent encode/evaluate
threads are safe.
"""
from __future__ import annotations

import pickle
import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Hashable

from ..obs import metrics as _metrics

# Process-wide telemetry series (repro.obs): unlike the per-instance
# hits/misses attributes these survive cache clears, so a run report shows
# cumulative cache behaviour even across benchmark phases.
_HIT_COUNTER = _metrics.counter("structure_cache.hit")
_MISS_COUNTER = _metrics.counter("structure_cache.miss")
_EVICT_COUNTER = _metrics.counter("structure_cache.evict")


@dataclass
class StructureEntry:
    """Everything reusable across traffic patterns for one design structure."""
    arrays: Any                # core.proxies.DeviceArrays (read-only)
    graph: Any = None          # core.graph.DenseGraph, if the builder kept it
    diameter: int | None = None   # routed diameter; filled lazily (batched)
    extra: dict = field(default_factory=dict)


def _entry_nbytes(entry: StructureEntry) -> int:
    """Approximate host-memory footprint of one entry: dense arrays plus any
    non-trivial objects retained in ``extra`` (e.g. the built Design kept for
    the optimizer's report masks) so the byte-budgeted eviction sees them."""
    total = 0
    for obj in (entry.arrays, entry.graph):
        if obj is None:
            continue
        for v in vars(obj).values():
            total += getattr(v, "nbytes", 0)
    for v in entry.extra.values():
        nb = getattr(v, "nbytes", None)
        if nb is not None:
            total += int(nb)
        elif not isinstance(v, (bool, int, float, str, bytes, type(None))):
            try:
                total += len(pickle.dumps(v, protocol=pickle.HIGHEST_PROTOCOL))
            except Exception:
                pass
    return total


class StructureCache:
    """Bounded, thread-safe LRU keyed by an opaque hashable structure key.

    Eviction is budgeted in *bytes* as well as entries: large-n sweeps carry
    multi-MB dense arrays per structure, so an entry-count bound alone could
    pin gigabytes of host memory."""

    def __init__(self, maxsize: int = 4096, max_bytes: int = 512 * 2**20):
        self.maxsize = maxsize
        self.max_bytes = max_bytes
        self._entries: OrderedDict[Hashable, StructureEntry] = OrderedDict()
        self._nbytes: dict[Hashable, int] = {}
        self._total_bytes = 0
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: Hashable) -> StructureEntry | None:
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.misses += 1
                _MISS_COUNTER.inc()
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            _HIT_COUNTER.inc()
            return entry

    def put(self, key: Hashable, entry: StructureEntry) -> StructureEntry:
        nbytes = _entry_nbytes(entry)
        with self._lock:
            if key in self._entries:
                self._total_bytes -= self._nbytes.get(key, 0)
            self._entries[key] = entry
            self._entries.move_to_end(key)
            self._nbytes[key] = nbytes
            self._total_bytes += nbytes
            while self._entries and (len(self._entries) > self.maxsize or
                                     self._total_bytes > self.max_bytes):
                old_key, _ = self._entries.popitem(last=False)
                self._total_bytes -= self._nbytes.pop(old_key, 0)
                _EVICT_COUNTER.inc()
        return entry

    def get_or_build(self, key: Hashable,
                     builder: Callable[[], StructureEntry]) -> StructureEntry:
        entry = self.get(key)
        if entry is None:
            # The builder runs outside the lock (it may be seconds of host
            # work); a concurrent builder for the same key just overwrites
            # with an equivalent entry.
            entry = self.put(key, builder())
        return entry

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._nbytes.clear()
            self._total_bytes = 0
            self.hits = 0
            self.misses = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def stats(self) -> dict:
        with self._lock:
            return {"size": len(self._entries), "bytes": self._total_bytes,
                    "hits": self.hits, "misses": self.misses,
                    "maxsize": self.maxsize, "max_bytes": self.max_bytes}


# The default process-wide cache shared by the DSE encoder and the ICI model.
GLOBAL_STRUCTURE_CACHE = StructureCache()
