"""Design IR for RapidChiplet: chiplets, placements, packaging, technology.

This mirrors the paper's input files (Fig. 2): chiplets, placement, topology,
packaging, technology, plus the design file that bundles them. All structures
are immutable dataclasses so designs are hashable work units for the DSE
engine (idempotent restartable sweeps).

Units:
  lengths  : mm
  latency  : cycles (link latency may be cycles/mm * length)
  area     : mm^2
  power    : W
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal


class DesignValidationError(ValueError):
    """Raised when a design's input files are inconsistent (paper §2.1.1)."""


@dataclass(frozen=True)
class Phy:
    """A PHY location within a chiplet, relative to the chiplet's origin
    (lower-left corner), before rotation."""
    x: float
    y: float


@dataclass(frozen=True)
class Chiplet:
    """A chiplet *type* (the library entry, reusable across placements)."""
    name: str
    width: float
    height: float
    phys: tuple[Phy, ...]
    internal_latency: float = 3.0   # cycles (paper §3.1 uses 3)
    phy_latency: float = 12.0       # cycles (paper §3.1 uses 12)
    power: float = 1.0              # W
    technology: str = "generic_7nm"
    # Fraction of total chiplet area usable for link bumps (split across PHYs).
    bump_area_fraction: float = 0.10
    # Relay capability: can traffic be routed *through* this chiplet?
    relay: bool = True

    @property
    def area(self) -> float:
        return self.width * self.height

    def validate(self) -> None:
        if self.width <= 0 or self.height <= 0:
            raise DesignValidationError(f"chiplet {self.name}: non-positive size")
        for i, p in enumerate(self.phys):
            if not (0 <= p.x <= self.width and 0 <= p.y <= self.height):
                raise DesignValidationError(
                    f"chiplet {self.name}: PHY {i} at ({p.x},{p.y}) outside die "
                    f"({self.width}x{self.height})")
        if not (0 < self.bump_area_fraction <= 1):
            raise DesignValidationError(
                f"chiplet {self.name}: bump_area_fraction must be in (0,1]")


@dataclass(frozen=True)
class PlacedChiplet:
    """One instance of a chiplet in the package. Rotation is in degrees
    counter-clockwise and must be a multiple of 90."""
    chiplet: str
    x: float
    y: float
    rotation: int = 0


@dataclass(frozen=True)
class Placement:
    chiplets: tuple[PlacedChiplet, ...]
    # On-interposer routers (active interposers only): absolute positions.
    interposer_routers: tuple[tuple[float, float], ...] = ()


# An endpoint of a link: ("chiplet", chiplet_index, phy_index) or
# ("router", router_index, 0).
Endpoint = tuple[Literal["chiplet", "router"], int, int]


@dataclass(frozen=True)
class Link:
    a: Endpoint
    b: Endpoint


@dataclass(frozen=True)
class Topology:
    links: tuple[Link, ...]


@dataclass(frozen=True)
class Packaging:
    """Packaging technology parameters (paper §2.1: packaging input file)."""
    name: str = "passive_interposer"
    # "manhattan" or "euclidean" physical link routing (paper §2.1.2).
    link_routing: Literal["manhattan", "euclidean"] = "manhattan"
    # Link latency model: latency = const + per_mm * length (set per_mm=0 for
    # length-independent links).
    link_latency_per_mm: float = 0.25   # cycles/mm (paper §3.1 uses 0.25)
    link_latency_const: float = 0.0
    # Bump geometry for the throughput proxy's bandwidth term.
    bump_pitch: float = 0.05            # mm  (50um microbump pitch)
    non_data_wires: int = 2             # N_ndw: clock/handshake wires per link
    # Active interposer router properties.
    has_interposer_routers: bool = False
    router_latency: float = 3.0         # cycles
    router_power: float = 0.1           # W per router
    # Power model: per-mm link power (length-dependent term, paper §2.1.4).
    link_power_per_mm: float = 0.0      # W/mm
    link_power_const: float = 0.0       # W per link
    # Cost model.
    packaging_cost_per_mm2: float = 0.02  # $ / mm^2 of interposer
    packaging_cost_base: float = 1.0      # $ fixed per package

    def validate(self) -> None:
        if self.link_routing not in ("manhattan", "euclidean"):
            raise DesignValidationError(f"unknown link routing {self.link_routing}")
        if self.bump_pitch <= 0:
            raise DesignValidationError("bump_pitch must be positive")


@dataclass(frozen=True)
class Technology:
    """Manufacturing technology node, for the yield/cost model (paper §2.1.4)."""
    name: str = "generic_7nm"
    wafer_radius: float = 150.0        # mm (300mm wafer)
    wafer_cost: float = 9000.0         # $
    defect_density: float = 0.001      # defects / mm^2
    critical_level_ratio: float = 0.5  # fraction of defects that kill the die
    clustering_alpha: float = 3.0      # negative-binomial clustering parameter


@dataclass(frozen=True)
class TrafficEntry:
    src: int
    dst: int
    amount: float


@dataclass(frozen=True)
class Design:
    """A complete design point = one evaluation unit.

    Mirrors the paper's `design` file which references one file from each
    input directory.
    """
    name: str
    chiplet_library: tuple[Chiplet, ...]
    placement: Placement
    topology: Topology
    packaging: Packaging
    technologies: tuple[Technology, ...] = (Technology(),)
    routing: str = "dijkstra_lowest_id"   # or "updown_random"
    routing_metric: Literal["hops", "latency"] = "hops"
    seed: int = 0

    def library(self) -> dict[str, Chiplet]:
        return {c.name: c for c in self.chiplet_library}

    def technology_map(self) -> dict[str, Technology]:
        return {t.name: t for t in self.technologies}

    @property
    def n_chiplets(self) -> int:
        return len(self.placement.chiplets)

    @property
    def n_routers(self) -> int:
        return len(self.placement.interposer_routers)

    @property
    def n_nodes(self) -> int:
        return self.n_chiplets + self.n_routers

    def replace(self, **kw) -> "Design":
        return dataclasses.replace(self, **kw)


def validate_design(design: Design) -> None:
    """Input validation (paper §2.1.1): every referenced entity must exist and
    be self-consistent. Raises DesignValidationError."""
    lib = design.library()
    for c in design.chiplet_library:
        c.validate()
    design.packaging.validate()
    tech = design.technology_map()
    for c in design.chiplet_library:
        if c.technology not in tech:
            raise DesignValidationError(
                f"chiplet {c.name}: unknown technology {c.technology!r}")
    n_c, n_r = design.n_chiplets, design.n_routers
    if n_c == 0:
        raise DesignValidationError("placement has no chiplets")
    for i, pc in enumerate(design.placement.chiplets):
        if pc.chiplet not in lib:
            raise DesignValidationError(
                f"placement[{i}]: unknown chiplet type {pc.chiplet!r}")
        if pc.rotation % 90 != 0:
            raise DesignValidationError(
                f"placement[{i}]: rotation {pc.rotation} not a multiple of 90")
    if design.placement.interposer_routers and not design.packaging.has_interposer_routers:
        raise DesignValidationError(
            "placement has interposer routers but packaging does not support them")
    phy_use: dict[tuple[int, int], int] = {}
    for li, link in enumerate(design.topology.links):
        for ep in (link.a, link.b):
            kind, idx, phy = ep
            if kind == "chiplet":
                if not (0 <= idx < n_c):
                    raise DesignValidationError(f"link[{li}]: chiplet index {idx} out of range")
                ctype = lib[design.placement.chiplets[idx].chiplet]
                if not (0 <= phy < len(ctype.phys)):
                    raise DesignValidationError(
                        f"link[{li}]: phy index {phy} out of range for {ctype.name} "
                        f"({len(ctype.phys)} PHYs)")
                key = (idx, phy)
                phy_use[key] = phy_use.get(key, 0) + 1
                if phy_use[key] > 1:
                    raise DesignValidationError(
                        f"link[{li}]: PHY {phy} of chiplet {idx} used by multiple links")
            elif kind == "router":
                if not (0 <= idx < n_r):
                    raise DesignValidationError(f"link[{li}]: router index {idx} out of range")
            else:
                raise DesignValidationError(f"link[{li}]: unknown endpoint kind {kind!r}")
        if link.a == link.b:
            raise DesignValidationError(f"link[{li}]: self-loop")


def validate_traffic(design: Design, traffic: list[TrafficEntry]) -> None:
    n = design.n_chiplets
    for i, t in enumerate(traffic):
        if not (0 <= t.src < n and 0 <= t.dst < n):
            raise DesignValidationError(
                f"traffic[{i}]: endpoint out of range (n_chiplets={n})")
        if t.amount < 0:
            raise DesignValidationError(f"traffic[{i}]: negative amount")
