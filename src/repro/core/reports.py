"""Area, power, and cost reports (paper §2.1.4).

* Area: sum of chiplet areas + interposer area (smallest enclosing rectangle).
* Power: sum of per-chiplet power + per-router power + (optionally
  length-dependent) link power.
* Cost: negative-binomial yield model per chiplet, dies-per-wafer geometry,
  plus interposer/packaging cost.

These are host-side (numpy) — they are cheap per design and feed the DSE
filters; the JAX hot loop is the latency/throughput proxies.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .design import Design, Technology
from .geometry import interposer_area, link_lengths


@dataclass(frozen=True)
class AreaReport:
    total_chiplet_area: float
    interposer_area: float


@dataclass(frozen=True)
class PowerReport:
    chiplet_power: float
    router_power: float
    link_power: float

    @property
    def total(self) -> float:
        return self.chiplet_power + self.router_power + self.link_power


@dataclass(frozen=True)
class CostReport:
    chiplet_costs: tuple[float, ...]
    interposer_cost: float
    packaging_cost: float

    @property
    def total(self) -> float:
        return sum(self.chiplet_costs) + self.interposer_cost + self.packaging_cost


def area_report(design: Design) -> AreaReport:
    lib = design.library()
    total = sum(lib[pc.chiplet].area for pc in design.placement.chiplets)
    return AreaReport(total_chiplet_area=total,
                      interposer_area=interposer_area(design))


def power_report(design: Design) -> PowerReport:
    lib = design.library()
    pkg = design.packaging
    chip_p = sum(lib[pc.chiplet].power for pc in design.placement.chiplets)
    router_p = pkg.router_power * design.n_routers
    lengths = link_lengths(design)
    link_p = float(np.sum(pkg.link_power_const + pkg.link_power_per_mm * lengths))
    return PowerReport(chiplet_power=chip_p, router_power=router_p,
                       link_power=link_p)


def die_yield(area: float, tech: Technology) -> float:
    """Negative-binomial yield model:
        Y = (1 + A * D0 * r / alpha)^(-alpha)
    with D0 the defect density, r the critical-level ratio, alpha the
    clustering parameter."""
    d_eff = tech.defect_density * tech.critical_level_ratio
    return float((1.0 + area * d_eff / tech.clustering_alpha)
                 ** (-tech.clustering_alpha))


def dies_per_wafer(area: float, tech: Technology) -> int:
    """Standard geometric approximation: pi*R^2/A - pi*2R/sqrt(2A)."""
    r = tech.wafer_radius
    n = np.pi * r * r / area - np.pi * 2.0 * r / np.sqrt(2.0 * area)
    return max(int(np.floor(n)), 1)


def die_cost(area: float, tech: Technology) -> float:
    """Per-good-die cost: wafer cost split over good dies."""
    return tech.wafer_cost / (dies_per_wafer(area, tech) * die_yield(area, tech))


def cost_report(design: Design, interposer_tech: Technology | None = None
                ) -> CostReport:
    """Paper §2.1.4: per-chiplet costs (yield model) + packaging cost.

    The interposer (if its area is nonzero) is manufactured in a mature node:
    by default a relaxed copy of the first technology with 10x lower defect
    density (interposers use old processes)."""
    lib = design.library()
    tech = design.technology_map()
    chip_costs = tuple(
        die_cost(lib[pc.chiplet].area, tech[lib[pc.chiplet].technology])
        for pc in design.placement.chiplets)
    ia = interposer_area(design)
    if interposer_tech is None:
        t0 = design.technologies[0]
        interposer_tech = Technology(
            name="interposer", wafer_radius=t0.wafer_radius,
            wafer_cost=t0.wafer_cost * 0.2,
            defect_density=t0.defect_density * 0.1,
            critical_level_ratio=t0.critical_level_ratio,
            clustering_alpha=t0.clustering_alpha)
    interposer_cost = die_cost(ia, interposer_tech) if ia > 0 else 0.0
    packaging_cost = (design.packaging.packaging_cost_base +
                      design.packaging.packaging_cost_per_mm2 * ia)
    return CostReport(chiplet_costs=chip_costs,
                      interposer_cost=interposer_cost,
                      packaging_cost=packaging_cost)
