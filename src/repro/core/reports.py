"""Area, power, and cost reports (paper §2.1.4).

* Area: sum of chiplet areas + interposer area (smallest enclosing rectangle).
* Power: sum of per-chiplet power + per-router power + (optionally
  length-dependent) link power.
* Cost: negative-binomial yield model per chiplet, dies-per-wafer geometry,
  plus interposer/packaging cost.

These are host-side (numpy) — they are cheap per design and feed the DSE
filters; the JAX hot loop is the latency/throughput proxies.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .design import Design, Technology
from .geometry import interposer_area, link_lengths


@dataclass(frozen=True)
class AreaReport:
    total_chiplet_area: float
    interposer_area: float


@dataclass(frozen=True)
class PowerReport:
    chiplet_power: float
    router_power: float
    link_power: float

    @property
    def total(self) -> float:
        return self.chiplet_power + self.router_power + self.link_power


@dataclass(frozen=True)
class CostReport:
    chiplet_costs: tuple[float, ...]
    interposer_cost: float
    packaging_cost: float

    @property
    def total(self) -> float:
        return sum(self.chiplet_costs) + self.interposer_cost + self.packaging_cost


def area_report(design: Design) -> AreaReport:
    lib = design.library()
    total = sum(lib[pc.chiplet].area for pc in design.placement.chiplets)
    return AreaReport(total_chiplet_area=total,
                      interposer_area=interposer_area(design))


def power_report(design: Design) -> PowerReport:
    lib = design.library()
    pkg = design.packaging
    chip_p = sum(lib[pc.chiplet].power for pc in design.placement.chiplets)
    router_p = pkg.router_power * design.n_routers
    lengths = link_lengths(design)
    link_p = float(np.sum(pkg.link_power_const + pkg.link_power_per_mm * lengths))
    return PowerReport(chiplet_power=chip_p, router_power=router_p,
                       link_power=link_p)


def die_yield_batch(area, defect_density, critical_level_ratio,
                    clustering_alpha) -> np.ndarray:
    """Vectorized negative-binomial yield model:
        Y = (1 + A * D0 * r / alpha)^(-alpha)
    with D0 the defect density, r the critical-level ratio, alpha the
    clustering parameter. All arguments broadcast."""
    d_eff = np.asarray(defect_density, np.float64) * critical_level_ratio
    alpha = np.asarray(clustering_alpha, np.float64)
    return (1.0 + np.asarray(area, np.float64) * d_eff / alpha) ** (-alpha)


def dies_per_wafer_batch(area, wafer_radius) -> np.ndarray:
    """Vectorized geometric approximation: pi*R^2/A - pi*2R/sqrt(2A)."""
    r = np.asarray(wafer_radius, np.float64)
    a = np.asarray(area, np.float64)
    n = np.pi * r * r / a - np.pi * 2.0 * r / np.sqrt(2.0 * a)
    return np.maximum(np.floor(n), 1.0)


def die_cost_batch(area, wafer_cost, wafer_radius, defect_density,
                   critical_level_ratio, clustering_alpha) -> np.ndarray:
    """Vectorized per-good-die cost: wafer cost split over good dies."""
    dpw = dies_per_wafer_batch(area, wafer_radius)
    y = die_yield_batch(area, defect_density, critical_level_ratio,
                        clustering_alpha)
    return np.asarray(wafer_cost, np.float64) / (dpw * y)


def die_yield(area: float, tech: Technology) -> float:
    d_eff = tech.defect_density * tech.critical_level_ratio
    return float((1.0 + area * d_eff / tech.clustering_alpha)
                 ** (-tech.clustering_alpha))


def dies_per_wafer(area: float, tech: Technology) -> int:
    return int(dies_per_wafer_batch(area, tech.wafer_radius))


def die_cost(area: float, tech: Technology) -> float:
    """Per-good-die cost: wafer cost split over good dies."""
    return tech.wafer_cost / (dies_per_wafer(area, tech) * die_yield(area, tech))


def _interposer_tech_default(design: Design) -> Technology:
    """The interposer is manufactured in a mature node: a relaxed copy of the
    first technology with 10x lower defect density (interposers use old
    processes). Shared by the per-design and batched cost paths."""
    t0 = design.technologies[0]
    return Technology(
        name="interposer", wafer_radius=t0.wafer_radius,
        wafer_cost=t0.wafer_cost * 0.2,
        defect_density=t0.defect_density * 0.1,
        critical_level_ratio=t0.critical_level_ratio,
        clustering_alpha=t0.clustering_alpha)


def cost_report(design: Design, interposer_tech: Technology | None = None
                ) -> CostReport:
    """Paper §2.1.4: per-chiplet costs (yield model) + packaging cost."""
    lib = design.library()
    tech = design.technology_map()
    chip_costs = tuple(
        die_cost(lib[pc.chiplet].area, tech[lib[pc.chiplet].technology])
        for pc in design.placement.chiplets)
    ia = interposer_area(design)
    if interposer_tech is None:
        interposer_tech = _interposer_tech_default(design)
    interposer_cost = die_cost(ia, interposer_tech) if ia > 0 else 0.0
    packaging_cost = (design.packaging.packaging_cost_base +
                      design.packaging.packaging_cost_per_mm2 * ia)
    return CostReport(chiplet_costs=chip_costs,
                      interposer_cost=interposer_cost,
                      packaging_cost=packaging_cost)


@dataclass(frozen=True)
class ReportArrays:
    """Per-design report scalars stacked over the design axis [B].

    This is the batched form the optimizer's constraint masks consume
    (area/power/cost budgets over whole populations); numbers match the
    per-design reports above exactly.

    ``reachable_fraction`` (ISSUE 9) surfaces disconnection explicitly:
    the fraction of ordered chiplet pairs (s != d) connected by the link
    graph — 1.0 for any connected design. The throughput proxy used to be
    the only signal (unreachable-pair flow accumulates on the next-hop
    self-loop diagonal and silently drives the proxy toward 0, see
    ``core.throughput.edge_flows``); this column makes the failure mode a
    first-class report instead. Defaults to all-ones when a constructor
    predates the column (old checkpoints, minimal tests)."""
    total_chiplet_area: np.ndarray
    interposer_area: np.ndarray
    power: np.ndarray
    cost: np.ndarray
    reachable_fraction: np.ndarray | None = None

    def __post_init__(self):
        if self.reachable_fraction is None:
            object.__setattr__(self, "reachable_fraction",
                               np.ones_like(np.asarray(self.power,
                                                       np.float64)))

    @property
    def total_area(self) -> np.ndarray:
        return self.total_chiplet_area + self.interposer_area


def connected_fraction(n_chiplets: int, n_routers: int, links) -> float:
    """Fraction of ordered chiplet pairs (s != d) connected through the
    link graph (chiplets + interposer routers as relay vertices); 1.0 when
    the design is connected, 0.0 when every chiplet is isolated.

    Pure-numpy union-find — deliberately independent of the routing
    machinery so the device path's reachable-fraction metric has a host
    oracle to test against."""
    n_total = n_chiplets + n_routers
    if n_chiplets <= 1:
        return 1.0
    parent = np.arange(n_total)

    def find(x: int) -> int:
        root = x
        while parent[root] != root:
            root = parent[root]
        while parent[x] != root:        # path compression
            parent[x], x = root, parent[x]
        return root

    def node_id(endpoint) -> int:
        kind, idx = endpoint[0], endpoint[1]
        return idx if kind == "chiplet" else n_chiplets + idx

    for link in links:
        ra, rb = find(node_id(link.a)), find(node_id(link.b))
        if ra != rb:
            parent[ra] = rb
    roots = np.asarray([find(i) for i in range(n_chiplets)])
    _, counts = np.unique(roots, return_counts=True)
    pairs = float(np.sum(counts * (counts - 1)))
    return pairs / float(n_chiplets * (n_chiplets - 1))


def adjacency_connected_fraction(bits: np.ndarray, pair_u: np.ndarray,
                                 pair_v: np.ndarray, n: int) -> np.ndarray:
    """``connected_fraction`` for a batch of adjacency bit-genomes [P, G]
    over the upper-triangle pair lists (``opt.space.AdjacencySpace``):
    fraction of ordered chiplet pairs (s != d) connected per genome."""
    bits = np.asarray(bits) % 2
    out = np.ones(len(bits), np.float64)
    if n <= 1:
        return out
    for b, row in enumerate(bits):
        parent = np.arange(n)

        def find(x: int) -> int:
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        for g in np.nonzero(row)[0]:
            ra, rb = find(int(pair_u[g])), find(int(pair_v[g]))
            if ra != rb:
                parent[ra] = rb
        roots = np.asarray([find(i) for i in range(n)])
        _, counts = np.unique(roots, return_counts=True)
        out[b] = float(np.sum(counts * (counts - 1))) / float(n * (n - 1))
    return out


def report_arrays(designs) -> ReportArrays:
    """Area/power/cost reports for a population of designs at once.

    Geometry (interposer bounding box, link lengths) stays per-design; the
    yield/cost arithmetic — the bulk of the report math on large populations —
    runs vectorized over one flattened chiplet axis with a segment-sum back to
    the design axis."""
    designs = list(designs)
    B = len(designs)
    if B == 0:
        z = np.zeros(0, np.float64)
        return ReportArrays(z, z, z, z, z)

    # Flatten every placed chiplet of every design into one axis.
    seg, c_area, c_power = [], [], []
    c_wradius, c_wcost, c_dd, c_clr, c_alpha = [], [], [], [], []
    ia = np.zeros(B, np.float64)
    router_p = np.zeros(B, np.float64)
    link_p = np.zeros(B, np.float64)
    pkg_cost = np.zeros(B, np.float64)
    i_wradius, i_wcost, i_dd, i_clr, i_alpha = (
        np.zeros(B, np.float64) for _ in range(5))
    reach = np.ones(B, np.float64)
    for b, d in enumerate(designs):
        reach[b] = connected_fraction(d.n_chiplets, d.n_routers,
                                      d.topology.links)
        lib = d.library()
        tech = d.technology_map()
        pkg = d.packaging
        for pc in d.placement.chiplets:
            ct = lib[pc.chiplet]
            t = tech[ct.technology]
            seg.append(b)
            c_area.append(ct.area)
            c_power.append(ct.power)
            c_wradius.append(t.wafer_radius)
            c_wcost.append(t.wafer_cost)
            c_dd.append(t.defect_density)
            c_clr.append(t.critical_level_ratio)
            c_alpha.append(t.clustering_alpha)
        ia[b] = interposer_area(d)
        lengths = link_lengths(d)
        router_p[b] = pkg.router_power * d.n_routers
        link_p[b] = float(np.sum(pkg.link_power_const +
                                 pkg.link_power_per_mm * lengths))
        pkg_cost[b] = pkg.packaging_cost_base + pkg.packaging_cost_per_mm2 * ia[b]
        it = _interposer_tech_default(d)
        i_wradius[b], i_wcost[b] = it.wafer_radius, it.wafer_cost
        i_dd[b], i_clr[b], i_alpha[b] = (it.defect_density,
                                         it.critical_level_ratio,
                                         it.clustering_alpha)

    seg = np.asarray(seg, np.int64)
    c_area = np.asarray(c_area, np.float64)
    chip_area = np.bincount(seg, weights=c_area, minlength=B)
    chip_power = np.bincount(seg, weights=np.asarray(c_power, np.float64),
                             minlength=B)
    chip_cost = die_cost_batch(c_area, c_wcost, c_wradius, c_dd, c_clr,
                               c_alpha)
    cost = np.bincount(seg, weights=chip_cost, minlength=B) + pkg_cost
    has_ia = ia > 0
    if has_ia.any():
        icost = die_cost_batch(np.where(has_ia, ia, 1.0), i_wcost,
                               i_wradius, i_dd, i_clr, i_alpha)
        cost = cost + np.where(has_ia, icost, 0.0)
    return ReportArrays(total_chiplet_area=chip_area, interposer_area=ia,
                        power=chip_power + router_p + link_p, cost=cost,
                        reachable_fraction=reach)
