"""Geometry: PHY absolute positions, link lengths, interposer bounding box.

Implements the paper's §2.1.2 link-length computation: "RapidChiplet computes
all link-lengths, considering the chiplet positions and rotations, the
placement of PHYs within the chiplets, and the link routing method (e.g.,
Manhattan, or direct)".
"""
from __future__ import annotations

import numpy as np

from .design import Design, Endpoint, DesignValidationError


def rotate_phy(px: float, py: float, w: float, h: float, rotation: int) -> tuple[float, float]:
    """Rotate a PHY's relative position by the chiplet rotation (CCW, multiples
    of 90 degrees). The chiplet footprint rotates with it, so the returned
    coordinates are relative to the rotated chiplet's lower-left corner."""
    r = rotation % 360
    if r == 0:
        return px, py
    if r == 90:
        # (x,y) -> (h - y, x); footprint becomes h x w
        return h - py, px
    if r == 180:
        return w - px, h - py
    if r == 270:
        return py, w - px
    raise DesignValidationError(f"rotation {rotation} not a multiple of 90")


def chiplet_footprint(w: float, h: float, rotation: int) -> tuple[float, float]:
    return (h, w) if rotation % 180 == 90 else (w, h)


def phy_positions(design: Design) -> np.ndarray:
    """Absolute position of every (chiplet, phy).

    Returns an object-free dense array ``pos[c][p] -> (x, y)`` encoded as a
    ragged-free array of shape [n_chiplets, max_phys, 2] with NaN padding.
    """
    lib = design.library()
    n = design.n_chiplets
    max_phys = max((len(lib[pc.chiplet].phys) for pc in design.placement.chiplets),
                   default=0)
    out = np.full((n, max(max_phys, 1), 2), np.nan, dtype=np.float64)
    for ci, pc in enumerate(design.placement.chiplets):
        ct = lib[pc.chiplet]
        for pi, phy in enumerate(ct.phys):
            rx, ry = rotate_phy(phy.x, phy.y, ct.width, ct.height, pc.rotation)
            out[ci, pi, 0] = pc.x + rx
            out[ci, pi, 1] = pc.y + ry
    return out


def endpoint_position(design: Design, ep: Endpoint,
                      phy_pos: np.ndarray | None = None) -> tuple[float, float]:
    kind, idx, phy = ep
    if kind == "router":
        return design.placement.interposer_routers[idx]
    if phy_pos is None:
        phy_pos = phy_positions(design)
    x, y = phy_pos[idx, phy]
    if np.isnan(x):
        raise DesignValidationError(f"endpoint {ep}: PHY has no position")
    return float(x), float(y)


def link_length(ax: float, ay: float, bx: float, by: float, routing: str) -> float:
    if routing == "manhattan":
        return abs(ax - bx) + abs(ay - by)
    if routing == "euclidean":
        return float(np.hypot(ax - bx, ay - by))
    raise DesignValidationError(f"unknown link routing {routing!r}")


def link_lengths(design: Design) -> np.ndarray:
    """Length of every link in design.topology, in topology order."""
    phy_pos = phy_positions(design)
    lengths = np.zeros(len(design.topology.links), dtype=np.float64)
    for li, link in enumerate(design.topology.links):
        ax, ay = endpoint_position(design, link.a, phy_pos)
        bx, by = endpoint_position(design, link.b, phy_pos)
        lengths[li] = link_length(ax, ay, bx, by, design.packaging.link_routing)
    return lengths


def interposer_bounding_box(design: Design) -> tuple[float, float, float, float]:
    """Smallest enclosing rectangle of all chiplets (paper §2.1.4).

    Returns (x0, y0, x1, y1)."""
    lib = design.library()
    x0 = y0 = np.inf
    x1 = y1 = -np.inf
    for pc in design.placement.chiplets:
        ct = lib[pc.chiplet]
        fw, fh = chiplet_footprint(ct.width, ct.height, pc.rotation)
        x0 = min(x0, pc.x)
        y0 = min(y0, pc.y)
        x1 = max(x1, pc.x + fw)
        y1 = max(y1, pc.y + fh)
    for (rx, ry) in design.placement.interposer_routers:
        x0, y0 = min(x0, rx), min(y0, ry)
        x1, y1 = max(x1, rx), max(y1, ry)
    return float(x0), float(y0), float(x1), float(y1)


def interposer_area(design: Design) -> float:
    x0, y0, x1, y1 = interposer_bounding_box(design)
    return max(0.0, (x1 - x0)) * max(0.0, (y1 - y0))


def check_overlaps(design: Design, spacing: float = 0.0) -> list[tuple[int, int]]:
    """Return pairs of chiplet indices whose footprints overlap (violating the
    placement). Used by input validation of generated placements."""
    lib = design.library()
    rects = []
    for pc in design.placement.chiplets:
        ct = lib[pc.chiplet]
        fw, fh = chiplet_footprint(ct.width, ct.height, pc.rotation)
        rects.append((pc.x, pc.y, pc.x + fw, pc.y + fh))
    bad = []
    eps = 1e-9
    for i in range(len(rects)):
        for j in range(i + 1, len(rects)):
            a, b = rects[i], rects[j]
            if (a[0] < b[2] - spacing + eps and b[0] < a[2] - spacing + eps and
                    a[1] < b[3] - spacing + eps and b[1] < a[3] - spacing + eps):
                bad.append((i, j))
    return bad
