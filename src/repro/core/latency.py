"""Latency proxy (paper §2.1.2), TPU-native.

The reference implementation walks the routing table per (source, destination)
pair — a data-dependent pointer chase. TPUs amortize gathers over [n, n]
blocks but hate data-dependent trip counts, so we compute *all* per-pair path
costs simultaneously with **path doubling** over the next-hop matrix:

    pos_1[u, d]  = next_hop[u, d]
    cost_1[u, d] = step_cost[u, next_hop[u, d]]          (0 if u == d)
    pos_2k[u, d]  = pos_k[pos_k[u, d], d]
    cost_2k[u, d] = cost_k[u, d] + cost_k[pos_k[u, d], d]

After ceil(log2(n)) doublings every route of length <= n-1 has converged
(pos == d), giving path costs for all n^2 pairs in O(log n) batched gathers.

``step_cost[u, v] = node_weight[u] + edge_latency[u, v]`` (PHY latencies are
already folded into edge latencies at graph construction), and the
destination's vertex weight is added once at the end, so the per-pair cost is
the sum of all vertex- and edge-weights on the path, exactly as the paper
specifies.

For *shortest-path* routing the same quantity is the min-plus matrix power of
the step-cost matrix; `path_cost_minplus` computes it via repeated min-plus
squaring — the Pallas kernel in ``repro.kernels.minplus`` accelerates that
product. The two agree whenever the routing table is shortest-path w.r.t. the
latency metric (property-tested).

Everything here is jit/vmap-friendly: fixed shapes, no Python branching on
data.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

BIG = 1e18   # finite stand-in for +inf inside min-plus algebra


def num_doubling_steps(n: int) -> int:
    """Doublings needed so paths of length <= n-1 converge."""
    return max(1, math.ceil(math.log2(max(n - 1, 2))) + 1)


@functools.partial(jax.jit, static_argnames=("n_steps",))
def path_cost_doubling(next_hop: jax.Array, step_cost: jax.Array,
                       node_weight: jax.Array, n_steps: int | None = None
                       ) -> jax.Array:
    """Per-pair path cost [n, n] under a next-hop routing table.

    Args:
      next_hop:    int32 [n, n]; next_hop[d, d] = d; next_hop[u, d] = u marks
                   "no route".
      step_cost:   float [n, n]; cost of leaving u over edge (u, v)
                   (= node_weight[u] + edge latency). Non-edges may be +inf or
                   garbage — they are never gathered for valid tables.
      node_weight: float [n]; destination vertex weight added at the end.

    Returns float32 [n, n]; entry (s, d) is the total path weight from s to d
    (all vertex + edge weights), +inf where unreachable, and
    node_weight[d] on the diagonal (the paper's formula applied to s == d).
    """
    n = next_hop.shape[0]
    if n_steps is None:
        n_steps = num_doubling_steps(n)
    # tables may arrive int16 (routing/device.py); widen for the gathers
    next_hop = next_hop.astype(jnp.int32)
    dest = jnp.arange(n, dtype=next_hop.dtype)[None, :]
    # Initial one-step tables.
    pos = next_hop
    first_cost = jnp.take_along_axis(step_cost, next_hop, axis=1)
    cost = jnp.where(pos == jnp.arange(n)[:, None], 0.0, first_cost)

    def body(_, carry):
        pos, cost = carry
        # pos2[u, d] = pos[pos[u, d], d]; cost2 = cost[u,d] + cost[pos[u,d], d]
        pos2 = jnp.take_along_axis(pos, pos, axis=0)
        cost2 = cost + jnp.take_along_axis(cost, pos, axis=0)
        return pos2, cost2

    pos, cost = jax.lax.fori_loop(0, n_steps, body, (pos, cost))
    reached = pos == dest
    total = cost + node_weight[None, :]
    return jnp.where(reached, total, jnp.inf).astype(jnp.float32)


def minplus_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """(min, +) matrix product, pure jnp (oracle for the Pallas kernel)."""
    return jnp.min(a[:, :, None] + b[None, :, :], axis=1)


@functools.partial(jax.jit, static_argnames=("n_steps", "use_kernel"))
def path_cost_minplus(step_cost: jax.Array, node_weight: jax.Array,
                      n_steps: int | None = None,
                      use_kernel: bool = False) -> jax.Array:
    """All-pairs shortest path cost via min-plus matrix squaring
    (Floyd–Warshall re-expressed as O(log n) dense (min,+) products — the
    MXU-friendly formulation; see kernels/minplus.py for the Pallas version).

    Only valid when routing is shortest-path w.r.t. the same metric.
    """
    n = step_cost.shape[0]
    if n_steps is None:
        n_steps = num_doubling_steps(n)
    if use_kernel:
        from ..kernels.ops import minplus_matmul as mm
    else:
        mm = minplus_ref
    eye0 = jnp.where(jnp.eye(n, dtype=bool), 0.0, BIG)
    d = jnp.minimum(jnp.where(jnp.isfinite(step_cost), step_cost, BIG), eye0)
    d = jax.lax.fori_loop(0, n_steps, lambda _, m: jnp.minimum(mm(m, m), BIG), d)
    total = d + node_weight[None, :]
    return jnp.where(d >= BIG * 0.5, jnp.inf, total).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("n_steps",))
def routed_hops(next_hop: jax.Array, n_steps: int | None = None) -> jax.Array:
    """Hop count of every routed path [n, n] (+inf where unreachable).
    ``int(max finite)`` is the exact routed diameter — the tight static hop
    bound for the flow accumulation in the throughput proxy."""
    n = next_hop.shape[0]
    ones = jnp.ones((n, n), dtype=jnp.float32)
    zeros = jnp.zeros((n,), dtype=jnp.float32)
    return path_cost_doubling(next_hop, ones, zeros, n_steps)


def routed_diameter(next_hop) -> int:
    hops = routed_hops(jnp.asarray(next_hop))
    finite = jnp.where(jnp.isfinite(hops), hops, 0.0)
    return int(jnp.max(finite))


@functools.partial(jax.jit, static_argnames=("n_steps",))
def _routed_diameter_batch(next_hop: jax.Array, n_steps: int) -> jax.Array:
    """Per-design routed diameter [B] for a stacked next-hop tensor [B, n, n]
    in one jitted call (sweep preparation computes the whole chunk's
    diameters at once instead of a jit dispatch + device round-trip per
    design). Padded vertices route to themselves (= unreachable) and are
    masked out, so padded and unpadded tables give the same diameter."""
    n = next_hop.shape[-1]
    ones = jnp.ones((n, n), dtype=jnp.float32)
    zeros = jnp.zeros((n,), dtype=jnp.float32)
    hops = jax.vmap(
        lambda nh: path_cost_doubling(nh, ones, zeros, n_steps))(next_hop)
    finite = jnp.where(jnp.isfinite(hops), hops, 0.0)
    return jnp.max(finite, axis=(1, 2))


def routed_diameter_batch(next_hop_batch) -> np.ndarray:
    """Host-facing wrapper: int64 [B] of routed diameters (>= 1 each, so the
    result is directly usable as a flow-accumulation hop bound)."""
    nh = jnp.asarray(next_hop_batch)
    dias = _routed_diameter_batch(nh, num_doubling_steps(nh.shape[-1]))
    return np.maximum(np.asarray(dias).astype(np.int64), 1)


@jax.jit
def latency_proxy(path_cost: jax.Array, traffic: jax.Array) -> jax.Array:
    """Paper §2.1.2: traffic-weighted average packet latency.

        L = sum_{(s,d,a)} a * path_cost(s,d) / sum a

    ``path_cost`` covers chiplet rows/cols only (the traffic matrix is
    [n_chiplets, n_chiplets]); pad/crop is the caller's job.
    """
    t = traffic
    num = jnp.sum(jnp.where(t > 0, t * path_cost, 0.0))
    den = jnp.sum(t)
    return (num / den).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("n_steps",))
def average_latency(next_hop: jax.Array, step_cost: jax.Array,
                    node_weight: jax.Array, traffic: jax.Array,
                    n_steps: int | None = None) -> jax.Array:
    """Fused latency proxy: path doubling + traffic-weighted mean."""
    n_c = traffic.shape[0]
    plat = path_cost_doubling(next_hop, step_cost, node_weight, n_steps)
    return latency_proxy(plat[:n_c, :n_c], traffic)
