"""The TPU pod's ICI modeled with RapidChiplet itself (DESIGN.md §3).

A TPU v5e pod is, structurally, exactly the object the paper models: an
interconnect of dies (chips instead of chiplets) with fixed per-link
bandwidth and a 2D-torus topology. This module builds that design, generates
the traffic matrices of the standard collectives (ring all-gather /
reduce-scatter / all-reduce, all-to-all), and predicts their sustained
bandwidth with the paper's throughput proxy. The framework's sharding layer
(repro.sharding.autoshard) ranks collective schedules with these predictions,
and benchmarks/collective_model.py cross-validates them against the analytic
ring formulas used in the roofline.

Hardware constants (per the assignment): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np

from .design import Packaging, Technology
from .graph import build_graph
from .proxies import prepare_arrays
from .throughput import throughput_proxy
from .latency import average_latency, routed_diameter
from ..topologies import make_design

TPU_V5E_PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
TPU_V5E_HBM_BW = 819e9             # bytes/s per chip
TPU_V5E_ICI_LINK_BW = 50e9         # bytes/s per link per direction


def tpu_pod_design(rows: int = 16, cols: int = 16, wrap: bool = True,
                   link_bw_bytes: float = TPU_V5E_ICI_LINK_BW):
    """A TPU pod as a RapidChiplet design: chips are 'chiplets' on a 2D
    torus (wrap=True) or mesh. Link bandwidths are overridden to the ICI
    budget (bytes/s) instead of bump-derived wire counts."""
    topo = "torus" if wrap else "mesh"
    design = make_design(
        topo, rows * cols,
        packaging=Packaging(name="tpu_ici", link_latency_per_mm=0.0,
                            link_latency_const=1.0),
        technology=Technology(name="tpu_chip"),
        chiplet_kwargs={"base_area": 300.0, "internal_latency": 1.0,
                        "phy_latency": 0.0, "technology": "tpu_chip"},
    )
    arrays, g = prepare_arrays(design)
    # Override bandwidth: each ICI link carries link_bw_bytes per second.
    g.adj_bw = np.where(np.isfinite(g.adj_lat), link_bw_bytes, 0.0)
    arrays = dataclasses.replace(arrays, adj_bw=g.adj_bw.astype(np.float32))
    return design, arrays, g


def _pod_structure(rows: int, cols: int, wrap: bool, link_bw: float):
    """Cached (arrays, routed diameter) for the pod design.

    estimate_collective is the autoshard inner loop — it must not rebuild the
    256-chip pod graph + routing table per call, so the built structure lives
    in the shared sweep-preparation cache (core.structure_cache). The cached
    arrays are shared and read-only.
    """
    from .structure_cache import GLOBAL_STRUCTURE_CACHE, StructureEntry

    key = ("tpu_pod", rows, cols, wrap, float(link_bw))

    def build():
        _, arrays, _ = tpu_pod_design(rows, cols, wrap, link_bw)
        return StructureEntry(arrays=arrays)

    entry = GLOBAL_STRUCTURE_CACHE.get_or_build(key, build)
    if entry.diameter is None:
        entry.diameter = max(routed_diameter(entry.arrays.next_hop), 1)
    return entry.arrays, entry.diameter


# ---------------------------------------------------------------------------
# Collective traffic patterns over the pod grid
# ---------------------------------------------------------------------------

def _ring_order(rows: int, cols: int, axis: str) -> list[list[int]]:
    """Chip-index rings along the chosen mesh axis ('data' = rows of the
    grid, i.e. ring over columns; 'model' = columns)."""
    rings = []
    if axis in ("data", "row"):
        for r in range(rows):
            rings.append([r * cols + c for c in range(cols)])
    elif axis in ("model", "col"):
        for c in range(cols):
            rings.append([r * cols + c for r in range(rows)])
    else:
        raise ValueError(f"unknown pod axis {axis!r}")
    return rings


def collective_traffic(kind: str, rows: int, cols: int, axis: str,
                       bytes_per_device: float) -> np.ndarray:
    """Traffic matrix of one collective over the pod grid.

    Ring collectives (all_gather / reduce_scatter / all_reduce) move
    (k-1)/k * bytes per device (2x for all_reduce) around the ring; XLA uses
    *bidirectional* rings, so each device sends half that volume to each ring
    neighbor. all_to_all sends bytes/k to every ring member.
    """
    n = rows * cols
    t = np.zeros((n, n), np.float64)
    rings = _ring_order(rows, cols, axis)
    for ring in rings:
        k = len(ring)
        if k < 2:
            continue
        if kind in ("all_gather", "reduce_scatter", "all_reduce"):
            per_neighbor = bytes_per_device * (k - 1) / k / 2.0
            if kind == "all_reduce":
                per_neighbor *= 2.0    # reduce-scatter + all-gather phases
            for i, u in enumerate(ring):
                t[u, ring[(i + 1) % k]] += per_neighbor
                t[u, ring[(i - 1) % k]] += per_neighbor
        elif kind == "all_to_all":
            per_pair = bytes_per_device / k
            for u in ring:
                for v in ring:
                    if u != v:
                        t[u, v] += per_pair
        else:
            raise ValueError(f"unknown collective {kind!r}")
    return t


@dataclass(frozen=True)
class CollectiveEstimate:
    kind: str
    axis: str
    bytes_per_device: float
    analytic_s: float          # ring formula at full per-link bandwidth
    proxy_sustained_fraction: float   # RapidChiplet throughput proxy
    proxy_s: float             # analytic_s / sustained fraction
    proxy_latency_cycles: float


def analytic_collective_time(kind: str, bytes_per_device: float, k: int,
                             link_bw: float = TPU_V5E_ICI_LINK_BW) -> float:
    """Standard *bidirectional*-ring formulas (the roofline's collective-term
    model): both link directions carry half the ring volume."""
    if k <= 1:
        return 0.0
    if kind == "all_gather" or kind == "reduce_scatter":
        return bytes_per_device * (k - 1) / k / (2.0 * link_bw)
    if kind == "all_reduce":
        return bytes_per_device * (k - 1) / k / link_bw
    if kind == "all_to_all":
        # Bisection bound on a bidirectional ring: (k/2)*(k/2)*(b/k) bytes
        # cross each way over 2 links x 2 directions -> k*b/8 per channel.
        return bytes_per_device * k / 8.0 / link_bw
    raise ValueError(f"unknown collective {kind!r}")


def estimate_collective(kind: str, axis: str, bytes_per_device: float,
                        rows: int = 16, cols: int = 16, wrap: bool = True,
                        link_bw: float = TPU_V5E_ICI_LINK_BW
                        ) -> CollectiveEstimate:
    """Predict a collective's time on the pod ICI using the paper's proxies.

    The throughput proxy's min_e B(e)/F(e) is "collective executions per
    second" when the traffic matrix is in bytes and B in bytes/s, so the
    predicted time is its reciprocal: max_e F(e)/B(e). One deviation from the
    paper's undirected-flow formula: TPU ICI links are full-duplex, so we
    evaluate *directed* flows against per-direction bandwidth (DESIGN.md §3).
    """
    from .throughput import edge_flows

    arrays, mh = _pod_structure(rows, cols, wrap, link_bw)
    t = collective_traffic(kind, rows, cols, axis, bytes_per_device)
    total = t.sum()
    k = cols if axis in ("data", "row") else rows
    analytic = analytic_collective_time(kind, bytes_per_device, k, link_bw)
    if total <= 0:
        return CollectiveEstimate(kind, axis, bytes_per_device,
                                  analytic, 1.0, analytic, 0.0)
    flow = np.asarray(edge_flows(arrays.next_hop, t.astype(np.float32),
                                 max_hops=mh))
    bw = arrays.adj_bw
    with np.errstate(divide="ignore", invalid="ignore"):
        per_edge_s = np.where((flow > 0) & (bw > 0), flow / bw, 0.0)
    proxy_s = float(per_edge_s.max())
    tn = (t / total).astype(np.float32)
    lat = float(average_latency(arrays.next_hop, arrays.step_cost,
                                arrays.node_weight, tn))
    frac = analytic / proxy_s if proxy_s > 0 else 1.0
    return CollectiveEstimate(kind=kind, axis=axis,
                              bytes_per_device=bytes_per_device,
                              analytic_s=analytic,
                              proxy_sustained_fraction=min(frac, 1.0),
                              proxy_s=proxy_s,
                              proxy_latency_cycles=lat)
