"""End-to-end design evaluation: the RapidChiplet core (paper Fig. 1).

``evaluate_design`` = validate -> build graph -> routing table -> latency &
throughput proxies -> area/power/cost reports. Host work (graph + routing) is
setup; the proxies run jitted. ``prepare_arrays`` exposes the dense device
arrays for the batched DSE engine (repro.dse), which pads and stacks many
designs and shards them over a pod mesh.
"""
from __future__ import annotations

from dataclasses import dataclass, asdict

import numpy as np

from .design import Design, validate_design
from .graph import DenseGraph, build_graph, step_cost_matrix
from .latency import average_latency, routed_diameter
from .throughput import throughput_proxy
from .reports import AreaReport, CostReport, PowerReport, area_report, cost_report, power_report
from ..routing.tables import build_routing_table


@dataclass
class DeviceArrays:
    """Dense, fixed-shape arrays consumed by the jitted proxies."""
    next_hop: np.ndarray     # int32 [n, n]
    step_cost: np.ndarray    # f32  [n, n]  (node_weight[u] + edge latency)
    node_weight: np.ndarray  # f32  [n]
    adj_bw: np.ndarray       # f32  [n, n]
    n_chiplets: int


@dataclass
class EvaluationReport:
    latency: float             # cycles, traffic-weighted mean packet latency
    throughput: float          # fraction of offered load sustained
    area: AreaReport
    power: PowerReport
    cost: CostReport

    def to_dict(self) -> dict:
        return {
            "latency": self.latency,
            "throughput": self.throughput,
            "total_chiplet_area": self.area.total_chiplet_area,
            "interposer_area": self.area.interposer_area,
            "power": self.power.total,
            "cost": self.cost.total,
        }


def prepare_arrays(design: Design, validate: bool = True) -> tuple[DeviceArrays, DenseGraph]:
    if validate:
        validate_design(design)
    g = build_graph(design)
    next_hop = build_routing_table(g, design.routing, design.routing_metric,
                                   design.seed)
    sc = step_cost_matrix(g)
    sc = np.where(np.isfinite(sc), sc, 0.0)   # never gathered for valid tables
    arrays = DeviceArrays(
        next_hop=next_hop.astype(np.int32),
        step_cost=sc.astype(np.float32),
        node_weight=g.node_weight.astype(np.float32),
        adj_bw=g.adj_bw.astype(np.float32),
        n_chiplets=g.n_chiplets,
    )
    return arrays, g


def evaluate_design(design: Design, traffic: np.ndarray,
                    validate: bool = True,
                    max_hops: int | None = None) -> EvaluationReport:
    """Evaluate one design under one traffic pattern (paper Fig. 1 flow)."""
    arrays, g = prepare_arrays(design, validate)
    if max_hops is None:
        # Exact routed diameter: tight static bound, no silent flow undercount.
        max_hops = max(routed_diameter(arrays.next_hop), 1)
    lat = float(average_latency(arrays.next_hop, arrays.step_cost,
                                arrays.node_weight,
                                traffic.astype(np.float32)))
    thr = float(throughput_proxy(arrays.next_hop, arrays.adj_bw,
                                 traffic.astype(np.float32),
                                 max_hops=max_hops))
    return EvaluationReport(
        latency=lat, throughput=thr,
        area=area_report(design),
        power=power_report(design),
        cost=cost_report(design),
    )
