"""Throughput proxy (paper §2.1.3), TPU-native.

Two edge properties are needed: the bandwidth B({u,v}) (computed at graph
construction from bump geometry) and the flow F({u,v}) — the sum of all
traffic routed over the edge. The proxy is then

    T = min_{e in E} B(e) / F(e) * total_traffic.

Computing F is the hot loop: the reference walks every route and increments
per-edge counters. The natural GPU port would use atomic scatter-adds; TPUs
have no fast scatter atomics, so we step all n^2 routes *simultaneously*,
hop by hop, and accumulate each hop's contributions with a
**scatter-as-matmul**: with one-hot row masks M_cur [P, n] and M_nxt [P, n]
for the current/next vertex of each pair p carrying traffic a_p, the flow
update is

    F += M_curᵀ @ (a[:, None] * M_nxt)        (an MXU matmul)

The Pallas kernel in ``kernels/flow_accum.py`` builds the masks on the fly
from iota comparisons inside VMEM (nothing materialized in HBM); the jnp
fallback here uses segment-sum scatter, which XLA handles fine on CPU.

The number of hop steps is the network diameter — a static bound passed in
(defaults to n-1, the worst case; topology generators provide tight bounds).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _dest_major_load0(next_hop: jax.Array, traffic: jax.Array) -> jax.Array:
    """Initial dest-major load L0[d, u] from a (possibly router-padded)
    traffic matrix: traffic[s, d] starts residing at s, destined for d."""
    n = next_hop.shape[0]
    n_c = traffic.shape[0]
    t = traffic.astype(jnp.float32)
    if n_c != n:
        # router padding: jnp.pad stays a `pad` under vmap, where an
        # .at[].set / dynamic_update_slice spelling batches to a scatter
        t = jnp.pad(t, ((0, n - n_c), (0, n - n_c)))
    return t.T


@functools.partial(jax.jit, static_argnames=("max_hops", "use_kernel",
                                              "adaptive"))
def edge_flows(next_hop: jax.Array, traffic: jax.Array,
               max_hops: int | None = None,
               use_kernel: bool = False,
               adaptive: bool = False) -> jax.Array:
    """Directed edge flows [n, n]: flow[u, v] = total traffic traversing the
    directed channel u->v under the routing table.

    traffic is [n_chiplets, n_chiplets]; routers never source traffic.

    The default path dispatches through the shared load-propagation
    primitive ``kernels.ops.load_propagate`` (fused Pallas kernel on TPU,
    scatter-free XLA loop elsewhere — see ``edge_flows_load`` for the
    formulation). ``use_kernel=True`` instead runs the per-route pair walk
    with the scatter-as-matmul ``flow_accumulate`` Pallas kernel — the
    alternative TPU story for very large n, kept as an independent
    implementation (and test oracle).

    ``adaptive=True`` replaces the fixed-length scan with a while_loop that
    stops once every route has reached its destination (``max_hops`` stays
    the safety bound). Same flows; the trip count becomes the actual routed
    diameter instead of the static bound. Under vmap the loop runs until
    the *batch* maximum diameter. Unreachable pairs (next_hop self-loops)
    never deliver, so both variants accumulate them on the diagonal for
    exactly ``max_hops`` hops (zero-bandwidth self-edges then drive the
    proxy to 0).
    """
    n = next_hop.shape[0]
    if max_hops is None:
        max_hops = n - 1
    if not use_kernel:
        from ..kernels.ops import load_propagate
        _, flow = load_propagate(next_hop, _dest_major_load0(next_hop,
                                                             traffic),
                                 max_hops=max_hops, adaptive=adaptive)
        return flow

    from ..kernels.load_prop import hop_loop
    from ..kernels.ops import flow_accumulate

    n_c = traffic.shape[0]
    t = jax.lax.dynamic_update_slice(
        jnp.zeros((n, n), dtype=jnp.float32),
        traffic.astype(jnp.float32), (0, 0))
    amount = t.ravel()                                   # [n*n]
    dest = jnp.tile(jnp.arange(n, dtype=next_hop.dtype), (n,))   # [n*n]
    cur0 = jnp.repeat(jnp.arange(n, dtype=next_hop.dtype), n)    # [n*n]

    def step(state):
        cur, flow = state
        nxt = next_hop[cur, dest]
        active = (cur != dest) & (amount > 0)
        contrib = jnp.where(active, amount, 0.0)
        flow = flow_accumulate(flow, cur, nxt, contrib)
        return jnp.where(active, nxt, cur), flow

    def still_active(state):
        return jnp.any((state[0] != dest) & (amount > 0))

    flow0 = jnp.zeros((n, n), dtype=jnp.float32)
    _, flow = hop_loop(step, (cur0, flow0), max_hops, adaptive, still_active)
    return flow


@functools.partial(jax.jit, static_argnames=("max_hops", "adaptive"))
def edge_flows_load(next_hop: jax.Array, traffic: jax.Array,
                    max_hops: int | None = None,
                    adaptive: bool = True) -> jax.Array:
    """``edge_flows`` as per-destination load propagation — scatter-free,
    for backends where XLA scatter-add is a scalar loop (CPU).

    State is the load matrix L[d, u] = traffic currently residing at u and
    destined for d. The routing table is static across hops, so its one-hot
    tensor OH[d, u, v] = [next_hop[u, d] = v] is built once; each hop is
    one contraction propagating the load, the summed per-hop loads
    W = Σ_j L_j are accumulated as a cheap [n, n] add, and the edge flows
    come from ONE final contraction

        flow[u, v] = Σ_d OH[d, u, v] · W[d, u]

    (every unit of load at u toward d crosses edge (u, next_hop[u, d])
    exactly once per hop). Delivered traffic (v == d) leaves the system;
    unreachable pairs (next_hop[u, d] = u) accumulate on the diagonal
    exactly like the walk in ``edge_flows``. This is now a thin alias for
    the shared primitive ``kernels.ops.load_propagate`` (one implementation
    of the fixed-length and adaptive variants, Pallas-fused on TPU); the
    fused genome pipeline (``dse.genomes._eval_proxies``) calls the same
    primitive and additionally extracts the traffic-weighted latency from
    the W tensor.
    """
    from ..kernels.ops import load_propagate

    _, flow = load_propagate(next_hop, _dest_major_load0(next_hop, traffic),
                             max_hops=max_hops, adaptive=adaptive)
    return flow


@jax.jit
def undirected_flows(flow: jax.Array) -> jax.Array:
    """Paper models links as undirected: F({u,v}) sums both directions."""
    return flow + flow.T


@functools.partial(jax.jit, static_argnames=("max_hops", "use_kernel",
                                              "directed", "adaptive"))
def throughput_proxy(next_hop: jax.Array, adj_bw: jax.Array,
                     traffic: jax.Array, max_hops: int | None = None,
                     use_kernel: bool = False,
                     directed: bool = False,
                     adaptive: bool = False) -> jax.Array:
    """Paper §2.1.3:

        T = min_{u,v} B({u,v}) / F({u,v}) * sum(traffic)

    Edges with zero flow do not constrain the minimum. Returns a float32
    scalar in units of total offered traffic (traffic generators normalize to
    1.0, so T is directly "sustainable fraction of offered load").

    ``directed=False`` is the paper's formula: F sums both directions of the
    undirected link against its total bandwidth B (wires shared between
    directions). ``directed=True`` evaluates each direction against B
    separately — the right structural model when comparing against a
    simulator (or hardware like TPU ICI) with full-duplex channels.
    """
    flow_dir = edge_flows(next_hop, traffic, max_hops, use_kernel, adaptive)
    f = flow_dir if directed else undirected_flows(flow_dir)
    bw = adj_bw.astype(jnp.float32)
    ratio = jnp.where(f > 0, bw / jnp.maximum(f, 1e-30), jnp.inf)
    min_ratio = jnp.min(ratio)
    total = jnp.sum(traffic).astype(jnp.float32)
    return (min_ratio * total).astype(jnp.float32)


@jax.jit
def reachable_fraction(next_hop: jax.Array, traffic: jax.Array) -> jax.Array:
    """Traffic-weighted fraction of reachable source/destination pairs.

    Unreachable pairs self-loop in the routing table
    (``next_hop[u, d] = u``, see ``routing.device``); their flow piles up
    on the diagonal and silently drives the throughput proxy to 0 while
    the latency proxy under-counts them entirely. This surfaces the
    condition as an explicit [0, 1] metric: 1.0 iff every pair with
    traffic can route. next_hop: [n, n] or [B, n, n]; traffic
    [n_c, n_c] (router-padded internally). Returns a scalar or [B]."""
    squeeze = next_hop.ndim == 2
    if squeeze:
        next_hop = next_hop[None]
    n = next_hop.shape[-1]
    t = _dest_major_load0(next_hop[0], traffic).T        # [n, n] src-major
    ids = jnp.arange(n, dtype=next_hop.dtype)
    reach = (next_hop != ids[None, :, None]) | (ids[:, None] ==
                                                ids[None, :])[None]
    total = jnp.maximum(jnp.sum(t), 1e-30)
    frac = (jnp.sum(t[None] * reach, axis=(1, 2)) / total
            ).astype(jnp.float32)
    return frac[0] if squeeze else frac


@functools.partial(jax.jit, static_argnames=("max_hops",))
def bottleneck_edges(next_hop: jax.Array, adj_bw: jax.Array,
                     traffic: jax.Array, max_hops: int | None = None
                     ) -> tuple[jax.Array, jax.Array]:
    """Diagnostics for DSE: per-edge saturation ratio F/B (higher = closer to
    the bottleneck) and the argmin edge index (u*n+v)."""
    flow_dir = edge_flows(next_hop, traffic, max_hops)
    f_und = undirected_flows(flow_dir)
    bw = adj_bw.astype(jnp.float32)
    ratio = jnp.where(f_und > 0, bw / jnp.maximum(f_und, 1e-30), jnp.inf)
    return ratio, jnp.argmin(ratio.ravel())
