"""Throughput proxy (paper §2.1.3), TPU-native.

Two edge properties are needed: the bandwidth B({u,v}) (computed at graph
construction from bump geometry) and the flow F({u,v}) — the sum of all
traffic routed over the edge. The proxy is then

    T = min_{e in E} B(e) / F(e) * total_traffic.

Computing F is the hot loop: the reference walks every route and increments
per-edge counters. The natural GPU port would use atomic scatter-adds; TPUs
have no fast scatter atomics, so we step all n^2 routes *simultaneously*,
hop by hop, and accumulate each hop's contributions with a
**scatter-as-matmul**: with one-hot row masks M_cur [P, n] and M_nxt [P, n]
for the current/next vertex of each pair p carrying traffic a_p, the flow
update is

    F += M_curᵀ @ (a[:, None] * M_nxt)        (an MXU matmul)

The Pallas kernel in ``kernels/flow_accum.py`` builds the masks on the fly
from iota comparisons inside VMEM (nothing materialized in HBM); the jnp
fallback here uses segment-sum scatter, which XLA handles fine on CPU.

The number of hop steps is the network diameter — a static bound passed in
(defaults to n-1, the worst case; topology generators provide tight bounds).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("max_hops", "use_kernel"))
def edge_flows(next_hop: jax.Array, traffic: jax.Array,
               max_hops: int | None = None,
               use_kernel: bool = False) -> jax.Array:
    """Directed edge flows [n, n]: flow[u, v] = total traffic traversing the
    directed channel u->v under the routing table.

    traffic is [n_chiplets, n_chiplets]; routers never source traffic.
    """
    n = next_hop.shape[0]
    n_c = traffic.shape[0]
    if max_hops is None:
        max_hops = n - 1
    # Pad traffic to [n, n] (router rows/cols zero).
    t = jnp.zeros((n, n), dtype=jnp.float32).at[:n_c, :n_c].set(
        traffic.astype(jnp.float32))
    amount = t.ravel()                                   # [n*n]
    dest = jnp.tile(jnp.arange(n, dtype=next_hop.dtype), (n,))   # [n*n]
    cur0 = jnp.repeat(jnp.arange(n, dtype=next_hop.dtype), n)    # [n*n]

    if use_kernel:
        from ..kernels.ops import flow_accumulate

        def body(carry, _):
            cur, flow = carry
            nxt = next_hop[cur, dest]
            active = (cur != dest) & (amount > 0)
            contrib = jnp.where(active, amount, 0.0)
            flow = flow_accumulate(flow, cur, nxt, contrib)
            return (jnp.where(active, nxt, cur), flow), None
    else:
        def body(carry, _):
            cur, flow = carry
            nxt = next_hop[cur, dest]
            active = (cur != dest) & (amount > 0)
            contrib = jnp.where(active, amount, 0.0)
            flat = cur.astype(jnp.int32) * n + nxt.astype(jnp.int32)
            flow = flow.ravel().at[flat].add(contrib).reshape(n, n)
            return (jnp.where(active, nxt, cur), flow), None

    (_, flow), _ = jax.lax.scan(
        body, (cur0, jnp.zeros((n, n), dtype=jnp.float32)), None,
        length=max_hops)
    return flow


@jax.jit
def undirected_flows(flow: jax.Array) -> jax.Array:
    """Paper models links as undirected: F({u,v}) sums both directions."""
    return flow + flow.T


@functools.partial(jax.jit, static_argnames=("max_hops", "use_kernel",
                                              "directed"))
def throughput_proxy(next_hop: jax.Array, adj_bw: jax.Array,
                     traffic: jax.Array, max_hops: int | None = None,
                     use_kernel: bool = False,
                     directed: bool = False) -> jax.Array:
    """Paper §2.1.3:

        T = min_{u,v} B({u,v}) / F({u,v}) * sum(traffic)

    Edges with zero flow do not constrain the minimum. Returns a float32
    scalar in units of total offered traffic (traffic generators normalize to
    1.0, so T is directly "sustainable fraction of offered load").

    ``directed=False`` is the paper's formula: F sums both directions of the
    undirected link against its total bandwidth B (wires shared between
    directions). ``directed=True`` evaluates each direction against B
    separately — the right structural model when comparing against a
    simulator (or hardware like TPU ICI) with full-duplex channels.
    """
    flow_dir = edge_flows(next_hop, traffic, max_hops, use_kernel)
    f = flow_dir if directed else undirected_flows(flow_dir)
    bw = adj_bw.astype(jnp.float32)
    ratio = jnp.where(f > 0, bw / jnp.maximum(f, 1e-30), jnp.inf)
    min_ratio = jnp.min(ratio)
    total = jnp.sum(traffic).astype(jnp.float32)
    return (min_ratio * total).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("max_hops",))
def bottleneck_edges(next_hop: jax.Array, adj_bw: jax.Array,
                     traffic: jax.Array, max_hops: int | None = None
                     ) -> tuple[jax.Array, jax.Array]:
    """Diagnostics for DSE: per-edge saturation ratio F/B (higher = closer to
    the bottleneck) and the argmin edge index (u*n+v)."""
    flow_dir = edge_flows(next_hop, traffic, max_hops)
    f_und = undirected_flows(flow_dir)
    bw = adj_bw.astype(jnp.float32)
    ratio = jnp.where(f_und > 0, bw / jnp.maximum(f_und, 1e-30), jnp.inf)
    return ratio, jnp.argmin(ratio.ravel())
