"""Visualization (paper §2.5): render designs as SVG (chiplets, PHYs,
links, on-interposer routers) and emit latency-vs-load curves from the
cycle simulator — the two plot kinds of the paper's Fig. 4.

No plotting dependencies: SVG is written directly; curve data is returned
as rows (and saved as CSV by the benchmarks) so any plotter can consume it.
"""
from __future__ import annotations

import html

import numpy as np

from .design import Design
from .geometry import chiplet_footprint, endpoint_position, phy_positions


def design_to_svg(design: Design, path: str | None = None,
                  scale: float = 8.0) -> str:
    """Render the placement + topology. Chiplets are rectangles, PHYs dots,
    links lines (Manhattan links drawn as L-shapes), routers diamonds."""
    lib = design.library()
    phy_pos = phy_positions(design)
    xs, ys = [], []
    for pc in design.placement.chiplets:
        ct = lib[pc.chiplet]
        fw, fh = chiplet_footprint(ct.width, ct.height, pc.rotation)
        xs += [pc.x, pc.x + fw]
        ys += [pc.y, pc.y + fh]
    for (rx, ry) in design.placement.interposer_routers:
        xs.append(rx)
        ys.append(ry)
    x0, y0, x1, y1 = min(xs), min(ys), max(xs), max(ys)
    pad = 2.0
    w = (x1 - x0 + 2 * pad) * scale
    h = (y1 - y0 + 2 * pad) * scale

    def tx(x):
        return (x - x0 + pad) * scale

    def ty(y):
        return h - (y - y0 + pad) * scale   # flip y for SVG

    out = [f'<svg xmlns="http://www.w3.org/2000/svg" width="{w:.0f}" '
           f'height="{h:.0f}" viewBox="0 0 {w:.0f} {h:.0f}">',
           f'<rect width="100%" height="100%" fill="#fafafa"/>']
    # links first (under chiplets)
    for link in design.topology.links:
        ax, ay = endpoint_position(design, link.a, phy_pos)
        bx, by = endpoint_position(design, link.b, phy_pos)
        if design.packaging.link_routing == "manhattan":
            out.append(f'<polyline points="{tx(ax):.1f},{ty(ay):.1f} '
                       f'{tx(bx):.1f},{ty(ay):.1f} {tx(bx):.1f},{ty(by):.1f}"'
                       f' fill="none" stroke="#4878cf" stroke-width="1.2"'
                       f' opacity="0.7"/>')
        else:
            out.append(f'<line x1="{tx(ax):.1f}" y1="{ty(ay):.1f}" '
                       f'x2="{tx(bx):.1f}" y2="{ty(by):.1f}" '
                       f'stroke="#4878cf" stroke-width="1.2" opacity="0.7"/>')
    # chiplets
    for ci, pc in enumerate(design.placement.chiplets):
        ct = lib[pc.chiplet]
        fw, fh = chiplet_footprint(ct.width, ct.height, pc.rotation)
        out.append(f'<rect x="{tx(pc.x):.1f}" y="{ty(pc.y + fh):.1f}" '
                   f'width="{fw * scale:.1f}" height="{fh * scale:.1f}" '
                   f'fill="#e8e8f0" stroke="#333" stroke-width="1"/>')
        out.append(f'<text x="{tx(pc.x + fw / 2):.1f}" '
                   f'y="{ty(pc.y + fh / 2) + 3:.1f}" font-size="{2.2 * scale:.0f}px" '
                   f'text-anchor="middle" fill="#333">{ci}</text>')
        for pi in range(len(ct.phys)):
            px, py = phy_pos[ci, pi]
            if np.isnan(px):
                continue
            out.append(f'<circle cx="{tx(px):.1f}" cy="{ty(py):.1f}" '
                       f'r="{0.4 * scale:.1f}" fill="#c44"/>')
    # routers
    for (rx, ry) in design.placement.interposer_routers:
        s = 0.8 * scale
        out.append(f'<path d="M {tx(rx):.1f} {ty(ry) - s:.1f} '
                   f'L {tx(rx) + s:.1f} {ty(ry):.1f} '
                   f'L {tx(rx):.1f} {ty(ry) + s:.1f} '
                   f'L {tx(rx) - s:.1f} {ty(ry):.1f} Z" '
                   f'fill="#7a7" stroke="#252"/>')
    out.append(f'<text x="4" y="{h - 6:.0f}" font-size="11px" fill="#666">'
               f'{html.escape(design.name)}</text>')
    out.append('</svg>')
    svg = "\n".join(out)
    if path:
        with open(path, "w") as f:
            f.write(svg)
    return svg


def latency_vs_load(design: Design, traffic: np.ndarray,
                    rates=(0.02, 0.05, 0.1, 0.2, 0.3, 0.4, 0.5),
                    config=None, engine: str = "fast") -> list[dict]:
    """Latency-vs-injection-rate curve from the cycle simulator (paper
    Fig. 4 right). Returns rows of {rate, latency, accepted, stable}.

    ``engine`` picks the simulator: ``'fast'`` (vectorized FastSim, the
    default) or ``'cycle'`` (the per-flit reference oracle)."""
    from ..sim import SimConfig, make_sim

    cfg = config or SimConfig(packet_size_flits=2, warmup_cycles=400,
                              measure_cycles=1200, drain_cycles=1500)
    sim = make_sim(design, traffic, cfg, engine=engine)
    if hasattr(sim, "run_batch"):
        # FastSim: all rates in one vectorized pass (identical stats)
        stats = sim.run_batch(list(rates), cfg)
    else:
        stats = []
        for r in rates:
            st = sim.run(r, cfg)
            stats.append(st)
            if not st.stable:
                break
    rows = []
    for r, st in zip(rates, stats):
        rows.append({"rate": r, "latency": st.avg_packet_latency,
                     "accepted": st.accepted_flits_per_node,
                     "stable": st.stable})
        if not st.stable:
            break
    return rows
