"""RapidChiplet core: the paper's contribution as a composable JAX module."""
from .design import (
    Chiplet, Phy, PlacedChiplet, Placement, Link, Topology, Packaging,
    Technology, TrafficEntry, Design, DesignValidationError,
    validate_design, validate_traffic,
)
from .graph import DenseGraph, build_graph, step_cost_matrix, traffic_matrix
from .latency import (
    path_cost_doubling, path_cost_minplus, latency_proxy, average_latency,
    num_doubling_steps,
)
from .throughput import edge_flows, throughput_proxy, bottleneck_edges
from .reports import (
    area_report, power_report, cost_report, die_yield, die_cost,
    ReportArrays, report_arrays,
)
from .proxies import evaluate_design, prepare_arrays, DeviceArrays, EvaluationReport

__all__ = [
    "Chiplet", "Phy", "PlacedChiplet", "Placement", "Link", "Topology",
    "Packaging", "Technology", "TrafficEntry", "Design",
    "DesignValidationError", "validate_design", "validate_traffic",
    "DenseGraph", "build_graph", "step_cost_matrix", "traffic_matrix",
    "path_cost_doubling", "path_cost_minplus", "latency_proxy",
    "average_latency", "num_doubling_steps",
    "edge_flows", "throughput_proxy", "bottleneck_edges",
    "area_report", "power_report", "cost_report", "die_yield", "die_cost",
    "ReportArrays", "report_arrays",
    "evaluate_design", "prepare_arrays", "DeviceArrays", "EvaluationReport",
]
