"""Dense graph IR for the proxies (paper §2.1.2-2.1.3).

The ICI is an undirected weighted graph G=(V,E): chiplets and on-interposer
routers are vertices, links are edges. We materialize it as dense [n,n]
matrices so the JAX proxies are fixed-shape linear algebra, vmappable across
design batches and shardable across a TPU mesh.

Vertex order: chiplets first (0..n_chiplets-1), then routers.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .design import Design, DesignValidationError
from .geometry import endpoint_position, phy_positions, link_length

INF = np.float64(np.inf)


@dataclass
class DenseGraph:
    """Dense representation of one ICI design.

    adj_lat[u,v]  : latency of edge {u,v} incl. PHY latencies at chiplet
                    endpoints; +inf if no edge. Symmetric.
    adj_bw[u,v]   : bandwidth B({u,v}) in data-wires (paper eq. for B); 0 if
                    no edge. Symmetric.
    node_weight[u]: chiplet internal latency or router latency.
    relay[u]      : whether traffic may be routed *through* u.
    lengths[u,v]  : physical link length in mm (0 if no edge).
    """
    n: int
    n_chiplets: int
    node_weight: np.ndarray
    adj_lat: np.ndarray
    adj_bw: np.ndarray
    lengths: np.ndarray
    relay: np.ndarray

    @property
    def n_routers(self) -> int:
        return self.n - self.n_chiplets

    def edge_list(self) -> list[tuple[int, int]]:
        """Undirected edges as (u, v) with u < v."""
        ii, jj = np.nonzero(np.isfinite(np.triu(self.adj_lat, k=1)))
        return list(zip(ii.tolist(), jj.tolist()))

    def degree(self) -> np.ndarray:
        return np.isfinite(self.adj_lat).sum(axis=1) - np.isfinite(
            np.diag(self.adj_lat))


def _phys_per_chiplet(design: Design) -> np.ndarray:
    """Number of PHYs actually *used* by links, per chiplet (for the bump-area
    fraction f_{c,{u,v}}: the chiplet's bump area is split across its used
    PHYs)."""
    used = np.zeros(design.n_chiplets, dtype=np.int64)
    for link in design.topology.links:
        for ep in (link.a, link.b):
            if ep[0] == "chiplet":
                used[ep[1]] += 1
    return used


def link_bandwidth(area: float, bump_area_fraction: float, n_used_phys: int,
                   bump_pitch: float, non_data_wires: int) -> int:
    """Paper §2.1.3:  B({u,v}) = floor(A_c * f_{c,{u,v}} / P_c^2) - N_ndw.

    f is the fraction of the chiplet area available to *this* link's bumps: we
    split the chiplet's total bump-area fraction evenly across its used PHYs.
    """
    if n_used_phys == 0:
        return 0
    f = bump_area_fraction / n_used_phys
    b = int(np.floor(area * f / (bump_pitch ** 2))) - non_data_wires
    return max(b, 0)


def build_graph(design: Design) -> DenseGraph:
    """Construct the dense graph for one design (paper §2.1.2-2.1.3)."""
    lib = design.library()
    pkg = design.packaging
    n_c, n_r = design.n_chiplets, design.n_routers
    n = n_c + n_r

    node_weight = np.zeros(n, dtype=np.float64)
    relay = np.ones(n, dtype=bool)
    for ci, pc in enumerate(design.placement.chiplets):
        ct = lib[pc.chiplet]
        node_weight[ci] = ct.internal_latency
        relay[ci] = ct.relay
    node_weight[n_c:] = pkg.router_latency   # routers always relay

    adj_lat = np.full((n, n), INF, dtype=np.float64)
    adj_bw = np.zeros((n, n), dtype=np.float64)
    lengths = np.zeros((n, n), dtype=np.float64)
    phy_pos = phy_positions(design)
    used_phys = _phys_per_chiplet(design)

    for li, link in enumerate(design.topology.links):
        ids = []
        phy_lat = 0.0
        bw_candidates = []
        for ep in (link.a, link.b):
            kind, idx, _ = ep
            if kind == "chiplet":
                ids.append(idx)
                ct = lib[design.placement.chiplets[idx].chiplet]
                # "If the link is connected to a chiplet rather than an
                # on-interposer router, the PHY-latency is added" (§2.1.2).
                phy_lat += ct.phy_latency
                bw_candidates.append(link_bandwidth(
                    ct.area, ct.bump_area_fraction, int(used_phys[idx]),
                    pkg.bump_pitch, pkg.non_data_wires))
            else:
                ids.append(n_c + idx)
        u, v = ids
        if u == v:
            raise DesignValidationError(f"link[{li}] connects a node to itself")
        ax, ay = endpoint_position(design, link.a, phy_pos)
        bx, by = endpoint_position(design, link.b, phy_pos)
        length = link_length(ax, ay, bx, by, pkg.link_routing)
        lat = pkg.link_latency_const + pkg.link_latency_per_mm * length + phy_lat
        # The bandwidth is limited by the more constrained endpoint. Links
        # between two routers have no bump constraint; model them as the max
        # seen bandwidth of chiplet links, or a large constant if none exist.
        bw = float(min(bw_candidates)) if bw_candidates else np.inf
        if np.isfinite(adj_lat[u, v]):
            raise DesignValidationError(
                f"duplicate link between nodes {u} and {v}")
        adj_lat[u, v] = adj_lat[v, u] = lat
        adj_bw[u, v] = adj_bw[v, u] = bw
        lengths[u, v] = lengths[v, u] = length

    # Router-router links without a bump constraint: cap at the largest
    # chiplet-link bandwidth so min() in the throughput proxy stays finite.
    inf_bw = ~np.isfinite(adj_bw)
    if inf_bw.any():
        finite = adj_bw[np.isfinite(adj_bw) & (adj_bw > 0)]
        cap = float(finite.max()) if finite.size else 1.0
        adj_bw[inf_bw] = cap

    return DenseGraph(n=n, n_chiplets=n_c, node_weight=node_weight,
                      adj_lat=adj_lat, adj_bw=adj_bw, lengths=lengths,
                      relay=relay)


def step_cost_matrix(g: DenseGraph) -> np.ndarray:
    """Cost of *leaving* vertex u over edge {u,v}: node_weight[u] + edge
    latency. The proxies add node_weight[dst] once at the end, so a full path
    cost is the sum of all vertex- and edge-weights on the path (paper
    §2.1.2)."""
    return g.node_weight[:, None] + g.adj_lat


def traffic_matrix(n_chiplets: int, entries) -> np.ndarray:
    """Dense [n_chiplets, n_chiplets] traffic matrix from (s, d, a) entries."""
    t = np.zeros((n_chiplets, n_chiplets), dtype=np.float64)
    for e in entries:
        t[e.src, e.dst] += e.amount
    return t
