"""Scalar pure-Python reference implementation of the paper's proxies.

This transcribes §2.1.2 / §2.1.3 literally (per-pair route walks, per-edge
dict counters) and serves as the oracle for the JAX implementations in
latency.py / throughput.py. Deliberately unoptimized.
"""
from __future__ import annotations

import numpy as np

from .graph import DenseGraph
from ..routing.tables import route_walk


def latency_reference(g: DenseGraph, next_hop: np.ndarray,
                      traffic: np.ndarray) -> float:
    """Average packet latency: traffic-weighted mean over routed paths of the
    sum of all vertex- and edge-weights on the path."""
    num = 0.0
    den = 0.0
    n_c = traffic.shape[0]
    for s in range(n_c):
        for d in range(n_c):
            a = traffic[s, d]
            if a <= 0 or s == d:
                continue
            path = route_walk(next_hop, s, d)
            lat = 0.0
            for v in path:
                lat += g.node_weight[v]
            for u, v in zip(path[:-1], path[1:]):
                lat += g.adj_lat[u, v]
            num += a * lat
            den += a
    return num / den


def edge_flows_reference(g: DenseGraph, next_hop: np.ndarray,
                         traffic: np.ndarray) -> dict[tuple[int, int], float]:
    """F({u,v}) per undirected edge (keys with u < v)."""
    flows: dict[tuple[int, int], float] = {}
    n_c = traffic.shape[0]
    for s in range(n_c):
        for d in range(n_c):
            a = traffic[s, d]
            if a <= 0 or s == d:
                continue
            path = route_walk(next_hop, s, d)
            for u, v in zip(path[:-1], path[1:]):
                key = (min(u, v), max(u, v))
                flows[key] = flows.get(key, 0.0) + a
    return flows


def throughput_reference(g: DenseGraph, next_hop: np.ndarray,
                         traffic: np.ndarray) -> float:
    """T = min_e B(e)/F(e) * total_traffic."""
    flows = edge_flows_reference(g, next_hop, traffic)
    min_ratio = np.inf
    for (u, v), f in flows.items():
        if f > 0:
            min_ratio = min(min_ratio, g.adj_bw[u, v] / f)
    return float(min_ratio * traffic.sum())
